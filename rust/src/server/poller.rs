//! Event-driven connection plane: every socket multiplexed onto a
//! fixed pool of poller threads (vendored epoll shim), with op dispatch
//! on a fixed worker pool -- thread count is flat in the connection
//! count, unlike the legacy thread-per-connection plane.
//!
//! # Structure
//!
//! `serve_event` spawns `pollers` poller threads and
//! `(2 * pollers).max(2)` dispatch workers. Poller 0 owns the listener
//! (folded into its readiness loop -- there is no separate accept
//! thread and no sleep-poll; the 100 ms `epoll_wait` slice is the one
//! timer in the plane, serving stop-flag observation, deadline scans
//! and the registry's idle-TTL tick). Accepted connections are handed
//! round-robin to the pollers; each poller owns its connections'
//! sockets exclusively -- it performs every read and every write, so no
//! socket is ever touched from two threads.
//!
//! # Per-connection state machine
//!
//! A connection incrementally decodes length-prefixed frames
//! (nonblocking reads in 64 KiB windows; the payload buffer grows only
//! as bytes arrive, so a length-prefix lie never costs an upfront
//! allocation). Complete frames queue in a small per-connection inbox
//! and are dispatched ONE AT A TIME, in arrival order, on the worker
//! pool -- the inbox is what gives **pipelining** (frame k+1 decodes
//! while frame k computes) while the serial dispatch keeps responses
//! strictly in request order. When the inbox is full, the connection's
//! read interest is dropped (level-triggered epoll would otherwise spin
//! on the unread bytes) and re-armed once a dispatch drains it.
//!
//! Workers never write to sockets: responses go through [`ConnWriter`]
//! into a per-connection ordered output buffer that the owning poller
//! flushes as the socket accepts bytes. The buffer is bounded
//! ([`HIGH_WATER`]) -- a worker streaming a large response blocks until
//! the peer drains, with a write-stall deadline so a dead peer cannot
//! pin a worker forever.
//!
//! # Deadline discipline (same contract as the threaded plane)
//!
//! `--conn-timeout` bounds BOTH idle time and whole-frame transit: the
//! deadline is measured from the connection's last completed activity,
//! and arriving bytes do NOT reset it -- a byte-at-a-time slow-loris
//! cannot trickle-reset its budget, while any frame completed in budget
//! refreshes it. Expiry answers a typed `timeout` frame (counted in
//! `conn_timeouts`) and closes. An oversized length prefix answers a
//! typed `too_large` frame and closes, after any already-queued frames
//! have been answered -- exactly the order the serial threaded plane
//! produces. Peer EOF at a frame boundary finishes in-flight work and
//! flushes before closing (half-close friendly); mid-frame EOF closes
//! silently. On stop, idle connections close immediately and in-flight
//! frames get the drain grace; pollers are joined before the workers,
//! and the workers before the registry's batcher shards are torn down.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;
use epoll::{
    Epoll, Event, EventFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};

use super::protocol::{self, err_obj, write_frame, DRAIN_GRACE, POLL_SLICE};
use super::registry::TableRegistry;
use super::{process_frame, reject_busy, FrameOut, WRITE_STALL_FALLBACK};

/// Token for the listener (registered on poller 0 only).
const TOKEN_LISTENER: u64 = 0;
/// Token for each poller's own wakeup eventfd.
const TOKEN_WAKE: u64 = 1;
/// Connection tokens are globally unique and start above the fixed ones.
static NEXT_TOKEN: AtomicU64 = AtomicU64::new(2);

/// Decoded frames a connection may queue ahead of dispatch. Small on
/// purpose: it bounds per-connection memory and how far a client can
/// run ahead, while still letting decode overlap compute.
const INBOX_CAP: usize = 8;
/// Output-buffer backpressure threshold: a worker writing a response
/// blocks once this much is buffered ahead of the socket.
const HIGH_WATER: usize = 1 << 20;
/// Bytes one connection may read per service round, so a firehose peer
/// cannot starve its poller's other connections (level-triggered epoll
/// re-reports the remainder immediately).
const READ_BUDGET: usize = 256 << 10;
/// Incremental read window -- same growth discipline as the threaded
/// plane's `read_frame_deadline`.
const READ_WINDOW: usize = 64 << 10;
/// Events fetched per `epoll_wait`.
const EVENTS_PER_WAIT: usize = 64;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // a worker panic is already isolated by process_frame's barrier;
    // plane bookkeeping must keep working regardless
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The cross-thread face of one poller: where sibling threads park new
/// connections and "look at this connection again" notes, plus the
/// eventfd that wakes it.
struct PollerHandle {
    pending: Mutex<Vec<TcpStream>>,
    dirty: Mutex<Vec<u64>>,
    wake: EventFd,
}

/// The dispatch-worker pool's shared work queue: connections with at
/// least one decoded frame waiting. A connection appears at most once
/// (the `queued` flag) and is re-queued by the worker that finishes it
/// while more frames wait -- round-robin fairness across connections.
struct WorkPool {
    queue: Mutex<VecDeque<Arc<ConnShared>>>,
    cv: Condvar,
    exit: AtomicBool,
}

/// Connection state shared between the owning poller and the workers.
struct ConnShared {
    state: Mutex<ConnState>,
    /// Signaled when the output buffer drains below [`HIGH_WATER`] (and
    /// on close), releasing a backpressured [`ConnWriter`].
    drained: Condvar,
    home: Arc<PollerHandle>,
    token: u64,
    write_stall: Duration,
}

#[derive(Default)]
struct ConnState {
    /// Decoded request frames awaiting dispatch, in arrival order.
    inbox: VecDeque<Vec<u8>>,
    /// Response bytes awaiting the socket; `out[out_pos..]` is unsent.
    out: Vec<u8>,
    out_pos: usize,
    /// A worker is running `process_frame` for this connection.
    dispatching: bool,
    /// Present in the work queue (at most one entry per connection).
    queued: bool,
    /// The poller closed the socket: writers must error out.
    closed: bool,
    /// Close the connection once `out` has fully flushed.
    close_after_flush: bool,
    /// A poller-originated typed close frame (timeout / too_large),
    /// appended only once no dispatch is active and the inbox is empty
    /// -- appending mid-response would corrupt the peer's framing.
    pending_close: Option<Vec<u8>>,
}

impl ConnShared {
    fn state(&self) -> MutexGuard<'_, ConnState> {
        lock(&self.state)
    }

    /// Ask the owning poller to look at this connection (flush fresh
    /// output, re-arm read interest, finalize a close).
    fn notify_home(&self) {
        lock(&self.home.dirty).push(self.token);
        self.home.wake.raise();
    }
}

/// The `io::Write` sink worker dispatches run against: appends into the
/// connection's ordered output buffer under backpressure and wakes the
/// owning poller to flush. Never touches the socket.
struct ConnWriter<'a> {
    conn: &'a ConnShared,
}

impl Write for ConnWriter<'_> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let conn = self.conn;
        let mut st = conn.state();
        let deadline = Instant::now() + conn.write_stall;
        while st.out.len() - st.out_pos >= HIGH_WATER {
            if st.closed {
                return Err(io::Error::new(
                    io::ErrorKind::BrokenPipe, "connection closed"));
            }
            let now = Instant::now();
            if now >= deadline {
                // same bound the threaded plane gets from its socket
                // write timeout: a peer that never drains cannot pin
                // this worker past the stall deadline
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut, "response write stalled"));
            }
            let (g, _) = conn
                .drained
                .wait_timeout(st, (deadline - now).min(POLL_SLICE))
                .unwrap_or_else(PoisonError::into_inner);
            st = g;
        }
        if st.closed {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe, "connection closed"));
        }
        let was_empty = st.out.len() == st.out_pos;
        st.out.extend_from_slice(buf);
        drop(st);
        if was_empty {
            // first bytes since the last flush: the poller may have
            // nothing armed for this connection -- wake it
            conn.notify_home();
        }
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Run the event plane until shutdown. Joins every plane thread before
/// tearing down the registry's batcher shards, exactly like the
/// threaded plane's drain.
pub(crate) fn serve_event(
    registry: &Arc<TableRegistry>,
    listener: TcpListener,
    pollers: usize,
) -> Result<()> {
    let stop = registry.stop_flag();
    let mut handles = Vec::with_capacity(pollers);
    for _ in 0..pollers {
        handles.push(Arc::new(PollerHandle {
            pending: Mutex::new(Vec::new()),
            dirty: Mutex::new(Vec::new()),
            wake: EventFd::new()?,
        }));
    }
    let pool = Arc::new(WorkPool {
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        exit: AtomicBool::new(false),
    });
    let n_workers = (2 * pollers).max(2);
    let mut workers: Vec<JoinHandle<()>> = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let pool = pool.clone();
        let registry = registry.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || {
            worker_loop(&pool, &registry, &stop)
        }));
    }
    let mut poller_threads: Vec<JoinHandle<Result<()>>> =
        Vec::with_capacity(pollers);
    let mut listener = Some(listener);
    for idx in 0..pollers {
        let registry = registry.clone();
        let stop = stop.clone();
        let handles = handles.clone();
        let pool = pool.clone();
        let lst = if idx == 0 { listener.take() } else { None };
        poller_threads.push(std::thread::spawn(move || {
            let res = Poller::run(idx, lst, registry, &stop, &handles, pool);
            if res.is_err() {
                // a poller dying (epoll failure) must not strand its
                // siblings or the accept path: stop the whole plane
                stop.store(true, Ordering::Relaxed);
                for h in &handles {
                    h.wake.raise();
                }
            }
            res
        }));
    }
    let mut first_err = None;
    for h in poller_threads {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err = first_err.or_else(|| {
                    Some(anyhow::anyhow!("poller thread panicked"))
                })
            }
        }
    }
    // pollers are gone: every connection is closed, so workers cannot
    // block on backpressure -- wake them out of the queue wait and join
    pool.exit.store(true, Ordering::Relaxed);
    pool.cv.notify_all();
    for h in workers {
        let _ = h.join();
    }
    registry.shutdown();
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// One dispatch worker: pop a connection with queued frames, run ONE
/// frame through the shared per-frame handler, re-queue the connection
/// if more frames wait. Serial-per-connection by construction
/// (`dispatching` flag), so responses are written in request order.
fn worker_loop(pool: &WorkPool, registry: &Arc<TableRegistry>, stop: &AtomicBool) {
    loop {
        let conn = {
            let mut q = lock(&pool.queue);
            loop {
                if let Some(c) = q.pop_front() {
                    break Some(c);
                }
                if pool.exit.load(Ordering::Relaxed) {
                    break None;
                }
                q = pool.cv.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some(conn) = conn else { return };
        let frame = {
            let mut st = conn.state();
            st.queued = false;
            if st.closed || st.dispatching {
                continue;
            }
            match st.inbox.pop_front() {
                Some(f) => {
                    st.dispatching = true;
                    f
                }
                None => continue,
            }
        };
        let mut w = ConnWriter { conn: &conn };
        let res = process_frame(&mut w, registry, stop, &frame);
        {
            let mut st = conn.state();
            st.dispatching = false;
            match res {
                Ok(FrameOut::Continue) => {
                    if !st.closed && !st.inbox.is_empty() && !st.queued {
                        st.queued = true;
                        drop(st);
                        lock(&pool.queue).push_back(conn.clone());
                        pool.cv.notify_one();
                    }
                }
                // shutdown acked / handler panicked (typed `internal`
                // already buffered) / the write side failed: close once
                // whatever made it into the buffer has flushed
                Ok(FrameOut::Shutdown) | Ok(FrameOut::Closed) | Err(_) => {
                    st.close_after_flush = true;
                }
            }
        }
        // always: the poller re-arms read interest (the inbox just
        // drained), flushes fresh output, or finalizes a close
        conn.notify_home();
    }
}

/// Incremental frame-decode state for one connection.
enum ReadState {
    Prefix { buf: [u8; 4], got: usize },
    Payload { len: usize, buf: Vec<u8> },
}

/// What one read service round concluded.
enum ReadOutcome {
    /// Socket drained (or budget spent): wait for the next event.
    NotReady,
    /// Inbox at capacity: read interest must drop until dispatch drains.
    InboxFull,
    /// Clean EOF at a frame boundary: drain in-flight work, flush, close.
    Eof,
    /// Mid-frame EOF or socket error: close silently.
    Gone,
    /// Length prefix over the frame cap: typed `too_large`, then close.
    TooLarge(u64),
}

/// One connection as its owning poller sees it. The socket lives here
/// and is only ever touched by that poller.
struct Conn {
    stream: TcpStream,
    shared: Arc<ConnShared>,
    rd: ReadState,
    /// Events currently registered with epoll.
    interest: u32,
    /// Last completed activity: accept, frame completion, dispatch
    /// completion, or output fully flushed. Deliberately NOT updated by
    /// arriving bytes -- the absolute whole-frame deadline that defeats
    /// slow-loris trickling.
    last_activity: Instant,
    /// Last time flushing made progress (write-stall detection).
    out_progress: Instant,
    peer_eof: bool,
    /// No further reads (typed close pending or already decided).
    read_dead: bool,
}

/// Decode as many frames as the socket, the read budget and the inbox
/// allow. Queues the connection for dispatch as frames complete.
fn read_ready(c: &mut Conn, pool: &WorkPool) -> ReadOutcome {
    let mut budget = READ_BUDGET;
    loop {
        match &mut c.rd {
            ReadState::Prefix { buf, got } => {
                match c.stream.read(&mut buf[*got..4]) {
                    Ok(0) => {
                        return if *got == 0 {
                            ReadOutcome::Eof
                        } else {
                            ReadOutcome::Gone // mid-prefix EOF
                        };
                    }
                    Ok(n) => {
                        *got += n;
                        budget = budget.saturating_sub(n);
                        if *got == 4 {
                            let len = u32::from_le_bytes(*buf) as usize;
                            if len > protocol::MAX_FRAME {
                                return ReadOutcome::TooLarge(len as u64);
                            }
                            if len == 0 {
                                // an empty frame is complete already;
                                // process_frame answers it `malformed`
                                c.rd = ReadState::Prefix { buf: [0; 4], got: 0 };
                                if frame_complete(c, Vec::new(), pool) {
                                    return ReadOutcome::InboxFull;
                                }
                            } else {
                                c.rd = ReadState::Payload {
                                    len,
                                    buf: Vec::with_capacity(len.min(READ_WINDOW)),
                                };
                            }
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        return ReadOutcome::NotReady;
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(_) => return ReadOutcome::Gone,
                }
            }
            ReadState::Payload { len, buf } => {
                let len = *len;
                let got = buf.len();
                // grow only as bytes arrive, in bounded windows -- a
                // prefix lie costs what the peer actually sends
                let want = (len - got).min(READ_WINDOW);
                buf.resize(got + want, 0);
                match c.stream.read(&mut buf[got..got + want]) {
                    Ok(0) => {
                        buf.truncate(got);
                        return ReadOutcome::Gone; // mid-frame EOF
                    }
                    Ok(n) => {
                        buf.truncate(got + n);
                        budget = budget.saturating_sub(n);
                        if buf.len() == len {
                            let frame = std::mem::take(buf);
                            c.rd = ReadState::Prefix { buf: [0; 4], got: 0 };
                            if frame_complete(c, frame, pool) {
                                return ReadOutcome::InboxFull;
                            }
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        buf.truncate(got);
                        return ReadOutcome::NotReady;
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                        buf.truncate(got);
                    }
                    Err(_) => {
                        buf.truncate(got);
                        return ReadOutcome::Gone;
                    }
                }
            }
        }
        if budget == 0 {
            return ReadOutcome::NotReady;
        }
    }
}

/// Queue a completed frame for dispatch. Returns true when the inbox
/// hit capacity (caller drops read interest).
fn frame_complete(c: &mut Conn, frame: Vec<u8>, pool: &WorkPool) -> bool {
    c.last_activity = Instant::now();
    let mut st = c.shared.state();
    st.inbox.push_back(frame);
    let full = st.inbox.len() >= INBOX_CAP;
    if !st.dispatching && !st.queued {
        st.queued = true;
        drop(st);
        lock(&pool.queue).push_back(c.shared.clone());
        pool.cv.notify_one();
    }
    full
}

/// Encode a typed server-originated close frame (the same bytes the
/// threaded plane writes before closing).
fn close_frame(code: &str, message: &str) -> Vec<u8> {
    let mut bytes = Vec::new();
    let _ = write_frame(&mut bytes, &err_obj(code, message, vec![]).to_string());
    bytes
}

struct Poller {
    idx: usize,
    ep: Epoll,
    home: Arc<PollerHandle>,
    handles: Vec<Arc<PollerHandle>>,
    pool: Arc<WorkPool>,
    registry: Arc<TableRegistry>,
    listener: Option<TcpListener>,
    conns: HashMap<u64, Conn>,
    /// Round-robin cursor for handing accepted connections to pollers
    /// (only poller 0 accepts, so only poller 0 advances it).
    rr: usize,
    timeout: Option<Duration>,
    write_stall: Duration,
    max_conns: Option<usize>,
}

impl Poller {
    fn run(
        idx: usize,
        listener: Option<TcpListener>,
        registry: Arc<TableRegistry>,
        stop: &AtomicBool,
        handles: &[Arc<PollerHandle>],
        pool: Arc<WorkPool>,
    ) -> Result<()> {
        let ep = Epoll::new()?;
        let home = handles[idx].clone();
        ep.add(home.wake.as_raw_fd(), EPOLLIN, TOKEN_WAKE)?;
        if let Some(l) = &listener {
            ep.add(l.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
        }
        let timeout = registry.config().conn_timeout;
        let mut p = Poller {
            idx,
            ep,
            home,
            handles: handles.to_vec(),
            pool,
            timeout,
            write_stall: timeout.unwrap_or(WRITE_STALL_FALLBACK),
            max_conns: registry.config().max_conns,
            registry,
            listener,
            conns: HashMap::new(),
            rr: 0,
        };
        let mut events = vec![Event::empty(); EVENTS_PER_WAIT];
        let mut draining_since: Option<Instant> = None;
        let mut last_scan = Instant::now();
        loop {
            let n = p.ep.wait(&mut events, POLL_SLICE.as_millis() as i32)?;
            let mut accept = false;
            for ev in events.iter().take(n) {
                // copy out of the (packed) event before matching
                let (bits, token) = (ev.events, ev.data);
                match token {
                    TOKEN_WAKE => p.home.wake.drain(),
                    TOKEN_LISTENER => accept = true,
                    t => p.conn_event(t, bits),
                }
            }
            if accept && draining_since.is_none() {
                p.accept_ready();
            }
            p.adopt_pending(draining_since.is_some());
            for token in {
                let mut d = lock(&p.home.dirty);
                std::mem::take(&mut *d)
            } {
                p.service(token);
            }
            if last_scan.elapsed() >= POLL_SLICE {
                last_scan = Instant::now();
                p.scan();
                if p.idx == 0 {
                    // the idle tick the threaded accept loop ran: with
                    // --ttl set, tables expire even with zero traffic
                    p.registry.maybe_expire_idle(&[]);
                }
            }
            if stop.load(Ordering::Relaxed) {
                let now = Instant::now();
                if draining_since.is_none() {
                    draining_since = Some(now);
                    // stop accepting: deregister and drop the listener
                    if let Some(l) = p.listener.take() {
                        let _ = p.ep.del(l.as_raw_fd());
                    }
                    p.adopt_pending(true);
                }
                let grace_over = now.duration_since(
                    draining_since.unwrap_or(now)) >= DRAIN_GRACE;
                let tokens: Vec<u64> = p.conns.keys().copied().collect();
                for token in tokens {
                    let idle = match p.conns.get(&token) {
                        Some(c) => {
                            let st = c.shared.state();
                            !st.dispatching
                                && st.inbox.is_empty()
                                && st.out_pos == st.out.len()
                        }
                        None => continue,
                    };
                    if idle || grace_over {
                        p.close_conn(token);
                    } else {
                        // keep flushing in-flight responses under grace
                        p.service(token);
                    }
                }
                if p.conns.is_empty() {
                    return Ok(());
                }
            }
        }
    }

    /// Accept every pending connection (poller 0 only): busy-reject at
    /// the cap, otherwise count it and hand it round-robin to a poller.
    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    let cs = self.registry.conn_stats();
                    if let Some(cap) = self.max_conns {
                        if cs.conns_open.load(Ordering::Relaxed) >= cap as u64 {
                            reject_busy(stream, &self.registry, cap);
                            continue;
                        }
                    }
                    cs.conns_open.fetch_add(1, Ordering::Relaxed);
                    cs.conns_total.fetch_add(1, Ordering::Relaxed);
                    let target = self.rr % self.handles.len();
                    self.rr = self.rr.wrapping_add(1);
                    if target == self.idx {
                        self.register(stream);
                    } else {
                        let h = &self.handles[target];
                        lock(&h.pending).push(stream);
                        h.wake.raise();
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                // transient per-connection accept failures
                // (ECONNABORTED and friends): try again next event
                Err(_) => return,
            }
        }
    }

    /// Take ownership of connections parked by poller 0. While draining
    /// they are closed instead (the accept happened before stop; the
    /// count must still balance).
    fn adopt_pending(&mut self, draining: bool) {
        let pending: Vec<TcpStream> = {
            let mut g = lock(&self.home.pending);
            std::mem::take(&mut *g)
        };
        for stream in pending {
            if draining {
                self.registry
                    .conn_stats()
                    .conns_open
                    .fetch_sub(1, Ordering::Relaxed);
                drop(stream);
            } else {
                self.register(stream);
            }
        }
    }

    /// Register one accepted connection with this poller.
    fn register(&mut self, stream: TcpStream) {
        let cs = self.registry.conn_stats();
        if stream.set_nonblocking(true).is_err()
            || stream.set_nodelay(true).is_err()
        {
            cs.conns_open.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let token = NEXT_TOKEN.fetch_add(1, Ordering::Relaxed);
        if self
            .ep
            .add(stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, token)
            .is_err()
        {
            cs.conns_open.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let shared = Arc::new(ConnShared {
            state: Mutex::new(ConnState::default()),
            drained: Condvar::new(),
            home: self.home.clone(),
            token,
            write_stall: self.write_stall,
        });
        let now = Instant::now();
        self.conns.insert(token, Conn {
            stream,
            shared,
            rd: ReadState::Prefix { buf: [0; 4], got: 0 },
            interest: EPOLLIN | EPOLLRDHUP,
            last_activity: now,
            out_progress: now,
            peer_eof: false,
            read_dead: false,
        });
    }

    /// Handle a readiness event for one connection.
    fn conn_event(&mut self, token: u64, bits: u32) {
        let mut gone = false;
        {
            let Some(c) = self.conns.get_mut(&token) else { return };
            if bits & (EPOLLERR | EPOLLHUP) != 0 {
                gone = true;
            } else if bits & (EPOLLIN | EPOLLRDHUP) != 0
                && !c.read_dead
                && !c.peer_eof
            {
                match read_ready(c, &self.pool) {
                    ReadOutcome::NotReady | ReadOutcome::InboxFull => {}
                    ReadOutcome::Eof => c.peer_eof = true,
                    ReadOutcome::Gone => gone = true,
                    ReadOutcome::TooLarge(nbytes) => {
                        // stop reading (the oversized payload was never
                        // consumed; the stream cannot be resynced), but
                        // answer typed ONLY after already-queued frames
                        // finish -- the order the serial plane produces
                        c.read_dead = true;
                        c.shared.state().pending_close =
                            Some(close_frame("too_large", &format!(
                                "frame of {nbytes} bytes exceeds the {} \
                                 byte cap", protocol::MAX_FRAME)));
                    }
                }
            }
        }
        if gone {
            self.close_conn(token);
            return;
        }
        self.service(token);
    }

    /// Bring one connection's poller-side state up to date: finalize a
    /// deferred typed close, flush buffered output, close when done,
    /// re-arm epoll interest.
    fn service(&mut self, token: u64) {
        let Some(c) = self.conns.get_mut(&token) else { return };
        {
            let mut st = c.shared.state();
            let quiescent = !st.dispatching && st.inbox.is_empty();
            if quiescent {
                if let Some(frame) = st.pending_close.take() {
                    st.out.extend_from_slice(&frame);
                    st.close_after_flush = true;
                } else if c.peer_eof {
                    // half-close: every queued frame was answered and
                    // the answers flush before the FIN below
                    st.close_after_flush = true;
                }
            }
        }
        if flush_out(c).is_err() {
            self.close_conn(token);
            return;
        }
        let done = {
            let st = c.shared.state();
            st.close_after_flush && st.out_pos == st.out.len()
        };
        if done {
            self.close_conn(token);
            return;
        }
        let (want_in, want_out) = {
            let st = c.shared.state();
            (
                !c.read_dead
                    && !c.peer_eof
                    && st.inbox.len() < INBOX_CAP
                    && !st.close_after_flush
                    && st.pending_close.is_none(),
                st.out_pos < st.out.len(),
            )
        };
        let mut interest = 0;
        if want_in {
            interest |= EPOLLIN | EPOLLRDHUP;
        }
        if want_out {
            interest |= EPOLLOUT;
        }
        if interest != c.interest
            && self
                .ep
                .modify(c.stream.as_raw_fd(), interest, token)
                .is_ok()
        {
            c.interest = interest;
        }
    }

    /// The per-slice deadline scan: idle/whole-frame timeouts (typed
    /// `timeout` close, counted), and write-stall force closes.
    fn scan(&mut self) {
        let now = Instant::now();
        let mut typed_timeout: Vec<u64> = Vec::new();
        let mut stalled: Vec<u64> = Vec::new();
        let mut revisit: Vec<u64> = Vec::new();
        for (&token, c) in self.conns.iter_mut() {
            let (busy, out_pending, closing) = {
                let st = c.shared.state();
                (
                    st.dispatching || !st.inbox.is_empty(),
                    st.out_pos < st.out.len(),
                    st.close_after_flush || st.pending_close.is_some(),
                )
            };
            if out_pending {
                if now.duration_since(c.out_progress) >= self.write_stall {
                    // a peer that stopped draining its responses: no
                    // typed frame (it would only grow the stuck buffer)
                    stalled.push(token);
                    continue;
                }
            } else {
                c.out_progress = now;
            }
            if busy || out_pending {
                // work in flight refreshes the activity clock; arriving
                // BYTES never do (slow-loris cannot trickle-reset)
                c.last_activity = now;
                continue;
            }
            if closing || c.peer_eof {
                // quiescent now: let service finalize the close
                revisit.push(token);
                continue;
            }
            if let Some(t) = self.timeout {
                if now.duration_since(c.last_activity) >= t {
                    typed_timeout.push(token);
                }
            }
        }
        for token in stalled {
            self.close_conn(token);
        }
        for token in typed_timeout {
            self.registry
                .conn_stats()
                .conn_timeouts
                .fetch_add(1, Ordering::Relaxed);
            if let Some(c) = self.conns.get_mut(&token) {
                c.read_dead = true;
                let mut st = c.shared.state();
                // quiescent (checked above): direct append cannot
                // interleave with a response
                let frame = close_frame(
                    "timeout", "connection deadline (--conn-timeout) expired");
                st.out.extend_from_slice(&frame);
                st.close_after_flush = true;
            }
            self.service(token);
        }
        for token in revisit {
            self.service(token);
        }
    }

    /// Close one connection: deregister, drop the socket, release any
    /// backpressured worker, balance the open-connection count.
    fn close_conn(&mut self, token: u64) {
        let Some(c) = self.conns.remove(&token) else { return };
        let _ = self.ep.del(c.stream.as_raw_fd());
        {
            let mut st = c.shared.state();
            st.closed = true;
            st.inbox.clear();
            st.pending_close = None;
        }
        c.shared.drained.notify_all();
        self.registry
            .conn_stats()
            .conns_open
            .fetch_sub(1, Ordering::Relaxed);
    }
}

/// Flush the connection's buffered output as far as the socket accepts.
/// `Err` means the socket failed (caller closes).
fn flush_out(c: &mut Conn) -> Result<(), ()> {
    let mut st = c.shared.state();
    let before = st.out_pos;
    while st.out_pos < st.out.len() {
        let pos = st.out_pos;
        match c.stream.write(&st.out[pos..]) {
            Ok(0) => return Err(()),
            Ok(n) => st.out_pos += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return Err(()),
        }
    }
    if st.out_pos > before {
        c.out_progress = Instant::now();
    }
    if st.out_pos == st.out.len() {
        st.out.clear();
        st.out_pos = 0;
    } else if st.out_pos > HIGH_WATER {
        // keep a long-lived slow connection's buffer bounded by what is
        // actually unsent
        st.out.drain(..st.out_pos);
        st.out_pos = 0;
    }
    if st.out.len() - st.out_pos < HIGH_WATER {
        c.shared.drained.notify_all();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use crate::server::{Client, EmbeddingServer, ServerConfig, TableRegistry};
    use std::sync::mpsc;
    use std::sync::Arc;

    /// Smoke test pinned to ONE poller: accept, lookup, a second
    /// request on the same connection, shutdown -- the full lifecycle
    /// on the smallest possible pool. (The default config already runs
    /// every other server test on the event plane at pollers = 2.)
    #[test]
    fn single_poller_serves_and_shuts_down() {
        let emb = crate::dpq::toy_embedding(20, 8, 4, 2, 1);
        let expect = emb.reconstruct_row(7);
        let registry = TableRegistry::new(ServerConfig {
            pollers: 1,
            ..ServerConfig::default()
        });
        registry.insert("emb", Arc::new(emb)).unwrap();
        let server = Arc::new(EmbeddingServer::new(registry));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        let rows = c.lookup_bin("emb", &[7]).unwrap();
        assert_eq!(rows.row(0), &expect[..]);
        // a second request on the same connection exercises the
        // dispatch-done -> re-arm -> read path
        let again = c.lookup_bin("emb", &[7, 7]).unwrap();
        assert_eq!(again.row(1), &expect[..]);
        c.shutdown().unwrap();
        h.join().unwrap();
    }
}
