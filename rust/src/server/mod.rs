//! Embedding-lookup server: serves compressed (DPQ) embeddings over TCP
//! with request micro-batching -- the L3 serving path demonstrating the
//! paper's inference claim (codebook lookup + concat is as cheap as a full
//! table lookup at a fraction of the memory).
//!
//! Wire protocol: length-prefixed JSON frames (u32 LE byte length + JSON).
//!   request:  {"op": "lookup", "ids": [1, 2, 3]}
//!             {"op": "lookup_bin", "ids": [...]}   (raw f32-LE response)
//!             {"op": "stats"}
//!             {"op": "shutdown"}
//!   response: {"ok": true, "vectors": [[...], ...]} | {"ok": true, ...}
//!   lookup_bin response: u32 LE frame length, then n*d f32 LE values
//!   (row-major). Binary lookups skip JSON float formatting entirely --
//!   see EXPERIMENTS.md §Perf for the measured speedup.
//!
//! Architecture: one thread per connection parses requests and strictly
//! validates ids -- every id must be a non-negative integer inside the
//! vocab; malformed or out-of-range ids are rejected, never clamped or
//! dropped (JSON with an `{"ok": false}` error object, binary with a
//! `u32::MAX` length sentinel, which can never be a real frame length; a
//! zero-length frame remains the valid response to an empty id list) --
//! and pushes a [`Pending`] onto the shared [`BatchQueue`]. A batcher
//! thread drains up to `max_batch` pending lookups at a time,
//! concatenates their ids, and reconstructs the whole micro-batch into
//! ONE flat row-major `Vec<f32>` sharded across the worker pool
//! (`util::pool`, thread count from `DPQ_THREADS` / `--threads`; small
//! batches run serial). Each pending request is then completed with a
//! zero-copy [`RowsSlice`] view of that buffer -- no per-id
//! `reconstruct_row` allocation, no `Vec<Vec<f32>>`, and no per-request
//! copy before wire encoding. Each row's gather is independent of chunk
//! placement, so served vectors are bit-identical for every thread
//! count. std-only (no tokio in the offline vendor set) -- the event loop
//! is threads + channels.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::dpq::CompressedEmbedding;
use crate::jsonx::Json;

/// Server statistics (exposed via the `stats` op).
#[derive(Default)]
pub struct Stats {
    pub requests: AtomicU64,
    pub ids_served: AtomicU64,
    pub batches: AtomicU64,
}

/// A request's reconstructed rows: a shared view into its micro-batch's
/// flat buffer (row-major, `len` = ids * d). No per-request copy is made;
/// the buffer is freed when the last handler finishes encoding its view.
struct RowsSlice {
    buf: Arc<Vec<f32>>,
    start: usize,
    len: usize,
}

impl RowsSlice {
    fn as_slice(&self) -> &[f32] {
        &self.buf[self.start..self.start + self.len]
    }
}

/// A pending lookup: ids + completion slot. The batcher fills the slot
/// with a [`RowsSlice`] view of the batch's flat reconstruction;
/// connection handlers slice or chunk it per protocol. Ids are validated
/// against the vocab by the connection handler BEFORE queueing -- the
/// batcher reconstructs unchecked.
struct Pending {
    ids: Vec<usize>,
    done: Arc<(Mutex<Option<RowsSlice>>, Condvar)>,
}

/// Strictly parse the request's `ids` array: every element must be a
/// non-negative integer JSON number. Anything else (negative, fractional,
/// string, null) returns `Ok(None)` so the caller can reject -- never
/// drop or saturate-clamp a malformed id (`-1 as usize` would silently
/// become id 0). A missing or non-array `ids` is a hard protocol error.
fn parse_ids(j: &Json, op: &str) -> Result<Option<Vec<usize>>> {
    let arr = j
        .get("ids")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("{op} without ids"))?;
    Ok(arr
        .iter()
        .map(|x| match x.as_f64() {
            Some(n) if n >= 0.0
                && n.fract() == 0.0
                && n <= usize::MAX as f64 => Some(n as usize),
            _ => None,
        })
        .collect())
}

/// Micro-batching queue: lookups accumulate here; the batcher drains.
pub struct BatchQueue {
    q: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    pub max_batch: usize,
}

impl BatchQueue {
    pub fn new(max_batch: usize) -> Self {
        BatchQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new(), max_batch }
    }

    fn push(&self, p: Pending) {
        self.q.lock().unwrap().push_back(p);
        self.cv.notify_one();
    }

    /// Pop up to max_batch entries, waiting up to `timeout` for the first.
    fn pop_batch(&self, timeout: Duration) -> Vec<Pending> {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            let (qq, _) = self.cv.wait_timeout(q, timeout).unwrap();
            q = qq;
        }
        let take = q.len().min(self.max_batch);
        q.drain(..take).collect()
    }
}

/// Reconstruct one drained micro-batch: every request's ids concatenated,
/// decoded into a single flat row-major [total, d] buffer sharded across
/// the worker pool (small batches run serial -- a thread spawn costs more
/// than a few hundred row gathers), then handed back per request in queue
/// order as contiguous slices. Each row's gather is independent of which
/// chunk it lands in, so the served bits never depend on the thread count.
fn run_batch(emb: &CompressedEmbedding, batch: &[Pending], stats: &Stats) {
    let d = emb.d;
    let total: usize = batch.iter().map(|p| p.ids.len()).sum();
    let mut all_ids: Vec<usize> = Vec::with_capacity(total);
    for p in batch {
        all_ids.extend_from_slice(&p.ids);
    }
    // Handlers validate before queueing, so an out-of-range id here is a
    // bug -- but an OOB panic (or an assert) would kill the batcher
    // thread and leave every waiting handler blocked on its condvar
    // forever. Keep the server alive in every build: log loudly and
    // answer the whole batch with empty views, which handlers turn into
    // explicit per-request errors.
    let vocab = emb.vocab();
    let valid = all_ids.iter().all(|&i| i < vocab);
    if !valid {
        eprintln!("server bug: unvalidated id reached the batcher; \
                   rejecting the whole micro-batch");
    }
    let mut flat = vec![0.0f32; if valid { total * d } else { 0 }];
    if valid {
        emb.reconstruct_rows_into(&all_ids, &mut flat);
        stats.ids_served.fetch_add(total as u64, Ordering::Relaxed);
    }
    // complete each request with a zero-copy view of the shared buffer
    let flat = Arc::new(flat);
    let mut off = 0;
    for p in batch {
        let len = if valid { p.ids.len() * d } else { 0 };
        let rows = RowsSlice { buf: flat.clone(), start: off, len };
        off += len;
        let (slot, cv) = &*p.done;
        *slot.lock().unwrap() = Some(rows);
        cv.notify_one();
    }
}

/// The embedding server over a compressed DPQ table.
pub struct EmbeddingServer {
    pub emb: Arc<CompressedEmbedding>,
    pub stats: Arc<Stats>,
    queue: Arc<BatchQueue>,
    stop: Arc<AtomicBool>,
}

impl EmbeddingServer {
    pub fn new(emb: CompressedEmbedding, max_batch: usize) -> Self {
        EmbeddingServer {
            emb: Arc::new(emb),
            stats: Arc::new(Stats::default()),
            queue: Arc::new(BatchQueue::new(max_batch)),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Bind + serve until a `shutdown` op arrives. Returns the bound
    /// address via the callback before blocking (port 0 supported).
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        // batcher thread
        let batcher = {
            let emb = self.emb.clone();
            let queue = self.queue.clone();
            let stop = self.stop.clone();
            let stats = self.stats.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let batch = queue.pop_batch(Duration::from_millis(20));
                    if batch.is_empty() {
                        continue;
                    }
                    stats.batches.fetch_add(1, Ordering::Relaxed);
                    run_batch(&emb, &batch, &stats);
                }
            })
        };
        // accept loop. Connection threads are detached: a thread exits when
        // its peer disconnects (or after serving `shutdown`). Joining them
        // here would deadlock shutdown against idle-but-open clients.
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let queue = self.queue.clone();
                    let stats = self.stats.clone();
                    let stop = self.stop.clone();
                    let vocab = self.emb.vocab();
                    let d = self.emb.d;
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, queue, stats, stop, vocab, d);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        let _ = batcher.join();
        Ok(())
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn handle_conn(
    mut stream: TcpStream,
    queue: Arc<BatchQueue>,
    stats: Arc<Stats>,
    stop: Arc<AtomicBool>,
    vocab: usize,
    d: usize,
) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        let req = match read_frame(&mut stream) {
            Ok(r) => r,
            Err(_) => return Ok(()), // peer closed
        };
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let j = Json::parse(&req).map_err(|e| anyhow!("bad request: {e}"))?;
        match j.get("op").and_then(|v| v.as_str()) {
            Some("lookup_bin") => {
                // malformed or out-of-range ids -> rejection sentinel:
                // u32::MAX is never a valid frame length (an empty id
                // list legitimately answers with a zero-length payload)
                let ids = match parse_ids(&j, "lookup_bin")? {
                    Some(ids) if ids.iter().all(|&i| i < vocab) => ids,
                    _ => {
                        stream.write_all(&u32::MAX.to_le_bytes())?;
                        continue;
                    }
                };
                let n_ids = ids.len();
                let done = Arc::new((Mutex::new(None), Condvar::new()));
                queue.push(Pending { ids, done: done.clone() });
                let (slot, cv) = &*done;
                let mut guard = slot.lock().unwrap();
                while guard.is_none() {
                    guard = cv.wait(guard).unwrap();
                }
                let rows = guard.take().unwrap();
                drop(guard);
                // rows arrive as a view of the batch's flat buffer:
                // encode straight to LE bytes, no per-row intermediates
                let flat = rows.as_slice();
                if flat.len() != n_ids * d {
                    // batcher answered with the defensive empty view (a
                    // co-batched request carried a bug-path invalid id):
                    // reject explicitly rather than serve a short frame
                    stream.write_all(&u32::MAX.to_le_bytes())?;
                    continue;
                }
                if flat.len() as u64 * 4 >= u32::MAX as u64 {
                    // fail loudly instead of wrapping the length prefix
                    bail!("lookup_bin response too large for a u32 frame");
                }
                let mut payload = Vec::with_capacity(flat.len() * 4);
                for v in flat {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                stream.write_all(&(payload.len() as u32).to_le_bytes())?;
                stream.write_all(&payload)?;
            }
            Some("lookup") => {
                // same validation as lookup_bin: malformed or
                // out-of-range ids are rejected, never clamped/dropped
                let ids = match parse_ids(&j, "lookup")? {
                    Some(ids) if ids.iter().all(|&i| i < vocab) => ids,
                    _ => {
                        write_frame(&mut stream, &Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", Json::str(
                                "ids must be integers in [0, vocab)")),
                        ]).to_string())?;
                        continue;
                    }
                };
                let n_ids = ids.len();
                let done = Arc::new((Mutex::new(None), Condvar::new()));
                queue.push(Pending { ids, done: done.clone() });
                let (slot, cv) = &*done;
                let mut guard = slot.lock().unwrap();
                while guard.is_none() {
                    guard = cv.wait(guard).unwrap();
                }
                let rows = guard.take().unwrap();
                drop(guard);
                if rows.as_slice().len() != n_ids * d {
                    // defensive empty view from the batcher (see
                    // run_batch): an explicit error, not ok:true with
                    // a short vector list
                    write_frame(&mut stream, &Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str("batch reconstruction failed")),
                    ]).to_string())?;
                    continue;
                }
                let arr = Json::arr(
                    rows.as_slice()
                        .chunks(d.max(1))
                        .map(|row| Json::arr(
                            row.iter().map(|&x| Json::num(x as f64)).collect()))
                        .collect(),
                );
                write_frame(&mut stream, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("vectors", arr),
                ]).to_string())?;
            }
            Some("stats") => {
                write_frame(&mut stream, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("requests", Json::num(stats.requests.load(Ordering::Relaxed) as f64)),
                    ("ids_served", Json::num(stats.ids_served.load(Ordering::Relaxed) as f64)),
                    ("batches", Json::num(stats.batches.load(Ordering::Relaxed) as f64)),
                ]).to_string())?;
            }
            Some("shutdown") => {
                stop.store(true, Ordering::Relaxed);
                write_frame(&mut stream, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                ]).to_string())?;
                return Ok(());
            }
            other => bail!("unknown op {other:?}"),
        }
    }
}

// ---- framing helpers (also used by the client below) ----

pub fn read_frame(stream: &mut TcpStream) -> Result<String> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 64 << 20 {
        bail!("frame too large: {n}");
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

pub fn write_frame(stream: &mut TcpStream, payload: &str) -> Result<()> {
    if payload.len() as u64 >= u32::MAX as u64 {
        // fail loudly instead of wrapping the u32 length prefix
        bail!("frame too large: {} bytes", payload.len());
    }
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload.as_bytes())?;
    Ok(())
}

/// Minimal blocking client for tests, benches and examples.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    pub fn lookup(&mut self, ids: &[usize]) -> Result<Vec<Vec<f32>>> {
        let req = Json::obj(vec![
            ("op", Json::str("lookup")),
            ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ]);
        write_frame(&mut self.stream, &req.to_string())?;
        let resp = Json::parse(&read_frame(&mut self.stream)?)
            .map_err(|e| anyhow!("bad response: {e}"))?;
        if resp.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            bail!("server error: {:?}", resp.get("error"));
        }
        Ok(resp
            .get("vectors")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing vectors"))?
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_f64().map(|f| f as f32))
                    .collect()
            })
            .collect())
    }

    /// Binary lookup: same semantics as `lookup`, raw f32-LE response.
    /// `d` is the embedding width (rows are returned flattened).
    pub fn lookup_bin(&mut self, ids: &[usize], d: usize) -> Result<Vec<Vec<f32>>> {
        let req = Json::obj(vec![
            ("op", Json::str("lookup_bin")),
            ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ]);
        write_frame(&mut self.stream, &req.to_string())?;
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n32 = u32::from_le_bytes(len);
        if n32 == u32::MAX {
            bail!("server rejected lookup_bin (id out of range?)");
        }
        let n = n32 as usize;
        let mut buf = vec![0u8; n];
        self.stream.read_exact(&mut buf)?;
        if n != ids.len() * d * 4 {
            bail!("unexpected payload size {n}");
        }
        Ok(buf
            .chunks_exact(d * 4)
            .map(|row| {
                row.chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect()
            })
            .collect())
    }

    pub fn stats(&mut self) -> Result<Json> {
        write_frame(&mut self.stream, &Json::obj(vec![
            ("op", Json::str("stats")),
        ]).to_string())?;
        Json::parse(&read_frame(&mut self.stream)?)
            .map_err(|e| anyhow!("bad response: {e}"))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        write_frame(&mut self.stream, &Json::obj(vec![
            ("op", Json::str("shutdown")),
        ]).to_string())?;
        let _ = read_frame(&mut self.stream);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    use crate::dpq::Codebook;
    use crate::tensor::{TensorF, TensorI};
    use crate::util::Rng;

    fn toy_emb(n: usize, k: usize, dg: usize, s: usize) -> CompressedEmbedding {
        let mut rng = Rng::new(1);
        let codes = TensorI::new(vec![n, dg],
                                 (0..n * dg).map(|_| rng.below(k) as i32).collect())
            .unwrap();
        let values = TensorF::new(vec![k, dg, s],
                                  (0..k * dg * s).map(|_| rng.normal()).collect())
            .unwrap();
        CompressedEmbedding::new(Codebook::from_codes(&codes, k).unwrap(),
                                 values, false).unwrap()
    }

    #[test]
    fn batch_queue_drains_up_to_max() {
        let q = BatchQueue::new(3);
        for _ in 0..5 {
            q.push(Pending {
                ids: vec![0],
                done: Arc::new((Mutex::new(None), Condvar::new())),
            });
        }
        let b1 = q.pop_batch(Duration::from_millis(1));
        assert_eq!(b1.len(), 3);
        let b2 = q.pop_batch(Duration::from_millis(1));
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn server_roundtrip_lookup_matches_local_reconstruct() {
        let emb = toy_emb(50, 8, 4, 3);
        let expect: Vec<Vec<f32>> =
            (0..5).map(|i| emb.reconstruct_row(i)).collect();
        let server = Arc::new(EmbeddingServer::new(emb, 16));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                .unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        let vecs = c.lookup(&[0, 1, 2, 3, 4]).unwrap();
        for (got, want) in vecs.iter().zip(&expect) {
            for (a, b) in got.iter().zip(want) {
                assert!((a - b).abs() < 1e-4);
            }
        }
        let stats = c.stats().unwrap();
        assert!(stats.get("ids_served").unwrap().as_usize().unwrap() >= 5);
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn binary_lookup_matches_json_lookup() {
        let emb = toy_emb(30, 8, 4, 2);
        let d = emb.d;
        let server = Arc::new(EmbeddingServer::new(emb, 16));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        let ids = [3usize, 7, 3, 29];
        let a = c.lookup(&ids).unwrap();
        let b = c.lookup_bin(&ids, d).unwrap();
        for (x, y) in a.iter().zip(&b) {
            for (p, q) in x.iter().zip(y) {
                assert!((p - q).abs() < 1e-4);
            }
        }
        assert!(c.lookup_bin(&[999], d).is_err());
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn server_rejects_out_of_range() {
        let server = Arc::new(EmbeddingServer::new(toy_emb(10, 4, 2, 2), 8));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        assert!(c.lookup(&[99]).is_err());
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// Regression: JSON and binary lookups must BOTH reject out-of-range
    /// ids (never clamp), and the connection must keep serving in-range
    /// requests afterwards.
    #[test]
    fn out_of_range_rejected_on_both_protocols() {
        let emb = toy_emb(10, 4, 2, 2);
        let d = emb.d;
        let boundary = emb.reconstruct_row(9);
        let server = Arc::new(EmbeddingServer::new(emb, 8));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        // vocab is 10: id 10 is the first invalid id on both protocols
        assert!(c.lookup(&[3, 10]).is_err());
        assert!(c.lookup_bin(&[3, 10], d).is_err());
        // a clamping server would serve id 10 as row 9; a rejecting one
        // still serves the real row 9 afterwards
        let got = c.lookup_bin(&[9], d).unwrap();
        assert_eq!(got[0], boundary);
        // empty id lists are valid on both protocols (the binary
        // rejection sentinel is u32::MAX, NOT a zero-length frame)
        assert_eq!(c.lookup(&[]).unwrap().len(), 0);
        assert_eq!(c.lookup_bin(&[], d).unwrap().len(), 0);
        // malformed ids (negative, fractional) are rejected too -- a
        // saturating/dropping parse would serve id 0 or a short response
        let mut raw = TcpStream::connect(addr).unwrap();
        for bad in [r#"{"op":"lookup","ids":[1,-2]}"#,
                    r#"{"op":"lookup","ids":[1.5]}"#] {
            write_frame(&mut raw, bad).unwrap();
            let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
            assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false),
                       "{bad} must be rejected");
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// The sharded batcher must split the flat reconstruction back into
    /// per-request slices in queue order, matching per-row reconstruction
    /// exactly for every thread count.
    #[test]
    fn run_batch_splits_per_request_and_matches_serial() {
        let emb = toy_emb(40, 8, 4, 3);
        let stats = Stats::default();
        let reqs: Vec<Vec<usize>> =
            vec![vec![0, 5, 39], vec![], vec![7], vec![39, 0, 0, 12]];
        for threads in [1usize, 2, 7] {
            crate::util::pool::with_threads(threads, || {
                let batch: Vec<Pending> = reqs
                    .iter()
                    .map(|ids| Pending {
                        ids: ids.clone(),
                        done: Arc::new((Mutex::new(None), Condvar::new())),
                    })
                    .collect();
                run_batch(&emb, &batch, &stats);
                for (p, ids) in batch.iter().zip(&reqs) {
                    let rows = p.done.0.lock().unwrap().take().unwrap();
                    let flat = rows.as_slice();
                    assert_eq!(flat.len(), ids.len() * emb.d);
                    for (ri, &id) in ids.iter().enumerate() {
                        assert_eq!(
                            &flat[ri * emb.d..(ri + 1) * emb.d],
                            &emb.reconstruct_row(id)[..],
                            "threads={threads} req row {ri}"
                        );
                    }
                }
            });
        }
        assert_eq!(
            stats.ids_served.load(Ordering::Relaxed),
            3 * reqs.iter().map(|r| r.len()).sum::<usize>() as u64
        );
    }

    #[test]
    fn timing_instant_smoke() {
        // keep Instant import exercised even if other tests change
        let t = Instant::now();
        assert!(t.elapsed() < Duration::from_secs(5));
    }
}
