//! Multi-table embedding-lookup server: serves any number of named
//! [`EmbeddingBackend`](crate::backend::EmbeddingBackend) tables (DPQ,
//! scalar-quant, low-rank, dense) over TCP with request micro-batching --
//! the L3 serving path demonstrating the paper's inference claim (a
//! codebook lookup + concat is as cheap as a full table lookup at a
//! fraction of the memory), at the scale where it pays: one server
//! process hosting many compressed tables behind one protocol.
//!
//! # Wire protocol v2 (and v1 compatibility)
//!
//! Every request is a length-prefixed JSON frame: u32 LE byte length,
//! then a JSON object. The `"v"` field selects the protocol version; a
//! frame **without** `"v"` is protocol **v1** -- the original
//! single-table protocol -- and is routed to the *default table* (the
//! first loaded, unless overridden), so pre-v2 clients keep working
//! unmodified. A `"v"` the server does not speak is answered with
//! `{"ok": false, "code": "unsupported_version", "max_v": 2}` -- that
//! frame IS the version negotiation: clients downshift to `max_v`.
//!
//! v2 requests (`"v": 2`) may carry `"table": "<name>"` on lookups and
//! stats to route by table; omitting it means the default table.
//!
//! Ops (normative spec with framing diagrams: `docs/WIRE_PROTOCOL.md`):
//!
//! | op              | v   | request fields            | response |
//! |-----------------|-----|---------------------------|----------|
//! | `lookup`        | 1,2 | `ids`, v2: `table`        | `{"ok":true,"n":..,"d":..,"vectors":[[..],..]}` |
//! | `lookup_bin`    | 1,2 | `ids`, v2: `table`        | binary, see below |
//! | `lookup_fanout` | 2   | `queries`: `[{table,ids},..]`, optional `stream` | one multi-section binary frame (streamed in chunks when `"stream": true`), see below |
//! | `score`         | 2   | `query` or `query_id`, `ids`, `table` | `{"ok":true,"path":..,"scores":[..]}` -- compute-on-codes dot products, see below |
//! | `topk`          | 2   | `query` or `query_id`, `k`, optional `lo`/`hi`, `table`, optional `stream` | `{"ok":true,"path":..,"ids":[..],"scores":[..]}` best-first; `"stream": true` answers binary chunked |
//! | `stats`         | 1,2 | v2: optional `table`      | counters + `batch_p50_s`/`batch_p99_s` latency (per table) |
//! | `tables`        | 2   |                           | `{"ok":true,"default":..,"tables":[{name,kind,vocab,d,..},..]}` |
//! | `load`          | 2   | `table`, `path`           | hot-load a `.dpq` file as a new table |
//! | `unload`        | 2   | `table`                   | hot-drop a table (resident or spilled); reports `was_default` + the default now in force |
//! | `demote`        | 2   | `table`                   | spill a resident table to the `--spill-dir` tier; next lookup reloads it |
//! | `set_replicas`  | 2   | `table`, `replicas`       | live-resize the table's batcher-shard replica count |
//! | `set_row_cache` | 2   | `table`, `bytes`          | resize the table's hot-row cache byte cap (0 disables); spilled tables record it for promotion |
//! | `snapshot`      | 2   | `dir`                     | serialize the registry into a server-side dir, `{"ok":true,"manifest":..}` |
//! | `fetch_artifact`| 2   | `sha256`                  | the spilled artifact with that content digest, streamed in chunks (re-verified server-side before serving); typed `not_found` for unknown digests |
//! | `shutdown`      | 1,2 |                           | `{"ok":true}`, then the server exits |
//!
//! **Binary lookup framing.** A v2 `lookup_bin` response is
//! self-describing: u32 LE frame length, then a `u32 n | u32 d` header,
//! then `n*d` f32 LE values (row-major) -- no client ever guesses the
//! embedding width. A v1 `lookup_bin` response keeps the legacy layout
//! (u32 LE length, then `n*d` f32 values, the caller knowing `d` out of
//! band). A `lookup_fanout` response is one frame of `u32 section_count`
//! followed by one `(n, d)`-headed section per query, in request order --
//! a multi-table recommender lookup in a single round trip. Rejections
//! use the `u32::MAX` length sentinel (never a real frame length; an
//! empty id list answers with a real, short frame); under v2 the
//! sentinel is followed by a JSON error frame naming the reason, so
//! binary errors are as typed as JSON ones.
//!
//! **Streamed responses.** A v2 `lookup_fanout` or `topk` request may
//! carry `"stream": true`: the response then starts with the
//! `u32::MAX - 1` continuation sentinel and arrives as bounded chunks
//! (each a `u32 LE len` of at most 256 KiB plus bytes) terminated by a
//! `u32 0` and a typed JSON terminal frame -- so results larger than
//! the 64 MiB single-frame cap (a full-vocab `topk`, a huge fan-out)
//! stream instead of rejecting `too_large`. The assembled bytes are
//! identical to what the unstreamed path would have produced.
//! Normative encoding: `docs/WIRE_PROTOCOL.md`.
//!
//! **Compute on codes.** The `score` and `topk` ops run similarity
//! directly over a table's compressed representation (the
//! [`scoring`](crate::scoring) module): DPQ and scalar-quant tables build a
//! per-query ADC lookup table and score candidates without ever
//! reconstructing a row; dense and low-rank tables take a pool-sharded
//! exact path. The query is either an explicit `"query"` f32 array
//! (rejected typed, `malformed`, if any value is non-finite or
//! overflows f32) or `"query_id"` -- a resident row of the same table.
//! Both ops route through the registry like any lookup: TTL touch, LRU
//! stamp, transparent spill promotion and the memory budget all apply,
//! and the scan is counted against the replica queue-depth signal.
//! Results are bit-identical for every thread/shard/replica count; ties
//! in `topk` break by ascending id.
//!
//! **Errors.** Every `{"ok": false}` response carries a machine `"code"`
//! (`bad_ids`, `no_such_table`, `unsupported_version`, `table_exists`,
//! `load_failed`, `reload_failed`, `needs_v2`, `unknown_op`, `internal`,
//! ...) beside the human `"error"` string; [`Client`] maps codes onto
//! [`WireError`] variants. Malformed or out-of-range ids are rejected,
//! never clamped or dropped. A `no_such_table` rejection carries the
//! three-state `"residency"` field (`evicted` / `spilled` / `lost`)
//! when the registry knows where the table went.
//!
//! # Architecture
//!
//! The default **event-driven connection plane** (Linux,
//! `--pollers N`, default 2) multiplexes every socket -- the listener
//! included -- onto a fixed pool of poller threads via a vendored
//! epoll shim ([`poller`]). Each connection is a small state machine
//! that carries the blocking plane's deadline discipline (idle +
//! absolute whole-frame deadlines, stop-flag observation within one
//! 100 ms tick, 64 KiB incremental payload windows) into nonblocking
//! reads; decoded frames are dispatched in order on a fixed worker
//! pool, and because decoding runs ahead of dispatch, a connection can
//! **pipeline** requests (frame k+1 decodes while frame k computes)
//! with responses written strictly in request order. Thread count is
//! flat in the connection count: pollers + dispatch workers, NOT one
//! thread per socket. `--pollers 0` (or a non-Linux build) falls back
//! to the legacy thread-per-connection plane, which shares the same
//! per-frame handler, so served bytes are bit-identical across planes.
//!
//! Either plane resolves the table in the [`TableRegistry`] and
//! strictly validates ids against that table's vocab. Validated
//! lookups are routed to the table's batcher shards (the id space is
//! range-partitioned across `shards_per_table` shards; see
//! [`registry`]), each of which drains micro-batches of up to
//! `max_batch` lookups and reconstructs them into one flat buffer
//! sharded across the worker pool (`util::pool`, thread count from
//! `DPQ_THREADS` / `--threads`; small batches run serial). Single-shard
//! answers are zero-copy views of the batch buffer. Row gathers are
//! independent of chunk and shard placement, so served vectors are
//! bit-identical for every thread count and shard count. std-only (no
//! tokio in the offline vendor set) -- the event loop is epoll +
//! threads + channels.

pub mod batcher;
pub mod clock;
pub mod fuzz;
#[cfg(target_os = "linux")]
pub mod poller;
pub mod protocol;
pub mod registry;
pub mod row_cache;
pub mod stats;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::dpq::CompressedEmbedding;
use crate::jsonx::Json;

pub use batcher::BatchQueue;
pub use clock::{Clock, ManualClock, MonotonicClock};
pub use protocol::{
    read_frame, write_frame, Client, Rows, TableDesc, WireError, VERSION,
};
pub use registry::{
    Residency, ServerConfig, SpillSeed, SpilledTable, TableEntry,
    TableRegistry, UnloadOutcome, MAX_REPLICAS, SNAPSHOT_FORMAT,
    SNAPSHOT_MANIFEST, SNAPSHOT_VERSION, SPILL_FORMAT, SPILL_MANIFEST,
};
pub use row_cache::RowCache;
pub use stats::{ConnStats, LatencyRing, ReplicaStats, Stats};

use batcher::Answer;
use protocol::{
    bin_sections_payload, err_frame, err_obj, frame_version, parse_ids,
    parse_query, read_frame_deadline, sections_payload_bytes,
    write_bin_reject_frame, write_bin_rows, write_bin_sections,
    write_stream_payload, FrameIn, MAX_FANOUT_SECTIONS,
};

/// Write timeout applied when `--conn-timeout` is disabled: a response
/// write to a peer that never drains its receive buffer must still
/// complete or fail in bounded time, or the graceful-shutdown join
/// would hang on that one connection thread forever.
const WRITE_STALL_FALLBACK: Duration = Duration::from_secs(30);

/// The embedding server over a [`TableRegistry`].
pub struct EmbeddingServer {
    registry: Arc<TableRegistry>,
}

impl EmbeddingServer {
    /// Serve the given registry (tables can still be added hot).
    pub fn new(registry: TableRegistry) -> Self {
        EmbeddingServer { registry: Arc::new(registry) }
    }

    /// Convenience: one DPQ table (which is also the default table, so
    /// v1 clients need no table name).
    pub fn single(name: &str, emb: CompressedEmbedding, max_batch: usize) -> Self {
        let registry = TableRegistry::new(ServerConfig {
            max_batch,
            ..ServerConfig::default()
        });
        registry
            .insert(name, Arc::new(emb))
            .expect("fresh registry cannot collide");
        EmbeddingServer::new(registry)
    }

    /// The registry backing this server (hot load/unload, stats).
    pub fn registry(&self) -> Arc<TableRegistry> {
        self.registry.clone()
    }

    /// The flag the accept loop watches; setting it stops the server.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.registry.stop_flag()
    }

    /// Bind + serve until a `shutdown` op arrives. Returns the bound
    /// address via the callback before blocking (port 0 supported).
    ///
    /// With [`ServerConfig::pollers`] > 0 (the default, Linux) this
    /// runs the event-driven plane: all sockets -- the listener
    /// included -- multiplexed onto that many poller threads plus a
    /// fixed dispatch-worker pool, with per-connection request
    /// pipelining. `pollers: 0` (or a non-Linux build) runs the legacy
    /// thread-per-connection plane. Both planes share the same
    /// per-frame handler ([`process_frame`]), so served bytes are
    /// bit-identical.
    ///
    /// Connection lifecycle: every accepted connection is tracked; a
    /// connection over the [`ServerConfig::max_conns`] cap is answered
    /// with a typed `busy` frame and closed without spawning a handler.
    /// Shutdown is graceful -- the server stops accepting, connections
    /// observe the stop flag within one [`protocol`] poll slice (idle
    /// connections close immediately; an in-flight frame gets a short
    /// drain grace), and every plane thread is JOINED before the
    /// registry's batcher shards are torn down, so no thread outlives
    /// `serve` and no in-flight batch is dropped mid-answer.
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        #[cfg(target_os = "linux")]
        {
            let pollers = self.registry.config().pollers;
            if pollers > 0 {
                return poller::serve_event(&self.registry, listener, pollers);
            }
        }
        self.serve_threaded(listener)
    }

    /// The legacy thread-per-connection plane (`--pollers 0`, and the
    /// fallback on non-Linux targets, where the epoll shim is absent).
    /// Kept bit-exactly equivalent to the event plane -- the
    /// cross-plane equivalence tests in `tests/conn_plane.rs` compare
    /// served bytes between the two.
    fn serve_threaded(&self, listener: TcpListener) -> Result<()> {
        let stop = self.registry.stop_flag();
        let max_conns = self.registry.config().max_conns;
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    // reap finished threads so the handle list tracks
                    // OPEN connections, not lifetime totals
                    conns.retain(|h| !h.is_finished());
                    let cs = self.registry.conn_stats();
                    if let Some(cap) = max_conns {
                        if cs.conns_open.load(Ordering::Relaxed) >= cap as u64 {
                            reject_busy(stream, &self.registry, cap);
                            continue;
                        }
                    }
                    cs.conns_open.fetch_add(1, Ordering::Relaxed);
                    cs.conns_total.fetch_add(1, Ordering::Relaxed);
                    let registry = self.registry.clone();
                    let stop = stop.clone();
                    conns.push(std::thread::spawn(move || {
                        // decrements conns_open on EVERY exit path,
                        // including a panic escaping handle_conn
                        let _open = OpenGuard(registry.clone());
                        let _ = handle_conn(stream, registry, stop);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // idle tick: with --ttl set, tables expire even on a
                    // server receiving no traffic at all (the sweep also
                    // rides on resolves; without a TTL this is a no-op).
                    // Throttled to one scan per clock-second, so the
                    // tick itself costs one atomic load.
                    self.registry.maybe_expire_idle(&[]);
                    conns.retain(|h| !h.is_finished());
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(e.into()),
            }
        }
        // graceful drain: stop accepting (listener drops), join every
        // connection thread (each observes the stop flag within a poll
        // slice; an in-flight frame finishes under the drain grace),
        // THEN close the batcher shards -- in-flight lookups complete
        // instead of failing typed at the finish line.
        drop(listener);
        for h in conns {
            let _ = h.join();
        }
        self.registry.shutdown();
        Ok(())
    }
}

/// Decrements `conns_open` when a connection thread exits, however it
/// exits -- the cap must never leak slots to panicking handlers.
struct OpenGuard(Arc<TableRegistry>);

impl Drop for OpenGuard {
    fn drop(&mut self) {
        self.0.conn_stats().conns_open.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Answer an over-cap connection with a typed `busy` frame and close
/// it. Best-effort with a short write timeout: the accept loop must
/// never block on a victim that won't read.
fn reject_busy(mut stream: TcpStream, registry: &TableRegistry, cap: usize) {
    registry
        .conn_stats()
        .busy_rejections
        .fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let _ = write_frame(
        &mut stream,
        &err_obj(
            "busy",
            &format!("server at --max-conns capacity ({cap}); retry later"),
            vec![],
        )
        .to_string(),
    );
}

/// The standard error frame for `e`, annotated with the three-state
/// `"residency"` field when a `no_such_table` rejection names a table
/// the registry knows something about: `"evicted"` (dropped under
/// memory pressure, not since reloaded), `"spilled"` (demoted to the
/// spill tier -- seen by requests whose table was demoted mid-flight;
/// a retry transparently reloads it) or `"lost"` (spilled but its
/// artifact is gone). For v2 compatibility the legacy boolean
/// `"evicted": true` still accompanies `"residency": "evicted"`.
fn annotated_err_frame(registry: &TableRegistry, e: &WireError) -> Json {
    let mut frame = err_frame(e);
    if let WireError::NoSuchTable(t) = e {
        let residency = match registry.residency(t) {
            Some(Residency::Spilled) => Some("spilled"),
            Some(Residency::Lost) => Some("lost"),
            Some(Residency::Resident) => None, // raced a reload: retryable
            None if registry.was_evicted(t) => Some("evicted"),
            None => None,
        };
        if let Some(r) = residency {
            if let Json::Obj(m) = &mut frame {
                m.insert("residency".into(), Json::str(r));
                if r == "evicted" {
                    m.insert("evicted".into(), Json::Bool(true));
                }
            }
        }
    }
    frame
}

/// Strictly parse and range-check a request's `ids` against `entry`'s
/// vocab -- the ONE validation both `lookup`/`lookup_bin` and every
/// `lookup_fanout` section go through, so id strictness can never
/// diverge between the ops. Malformed or out-of-range ids are a typed
/// `bad_ids` rejection, never clamped or dropped.
fn validate_ids(
    entry: &TableEntry,
    j: &Json,
    op: &str,
) -> Result<Vec<usize>, WireError> {
    let vocab = entry.backend.vocab();
    let bad = || WireError::Rejected {
        code: "bad_ids".into(),
        message: format!(
            "ids must be integers in [0, {vocab}) for table {:?}", entry.name),
    };
    match parse_ids(j, op)? {
        None => Err(bad()),
        Some(ids) => {
            if ids.iter().any(|&i| i >= vocab) {
                return Err(bad());
            }
            Ok(ids)
        }
    }
}

/// The error for a batcher that failed a request (`wait()` returned
/// `None`): if the table was unloaded, evicted or DEMOTED while the
/// request was in flight, that is a routine, retryable `no_such_table`
/// (annotated with `residency`/`evicted` where applicable; a demoted
/// table's retry transparently reloads it) -- only a failure on a table
/// that is STILL resident is the genuine `internal` bug path. Applies
/// to whole `lookup_fanout` frames too: one demoted-mid-flight section
/// rejects the entire frame, keeping the op all-or-nothing.
fn batch_failure_err(registry: &TableRegistry, entry: &TableEntry) -> WireError {
    match registry.get(&entry.name) {
        Some(current) if std::ptr::eq(&*current, entry) => WireError::Rejected {
            code: "internal".into(),
            message: "batch reconstruction failed".into(),
        },
        _ => WireError::NoSuchTable(entry.name.clone()),
    }
}

/// The CURRENT entry to retry a failed lookup against, when (and only
/// when) the failure was a live `set_replicas` swap: the table must be
/// resident under a DIFFERENT entry serving the SAME BACKEND
/// ALLOCATION -- `set_replicas` clones the backend `Arc` into the new
/// entry, so backend identity (not mere shape equality) is the exact
/// discriminator. An unload + reload of a different same-shape
/// artifact under the same name has a different backend and correctly
/// returns `None`: replaying against it would silently serve data the
/// request never targeted. On `None` the caller rejects with
/// [`batch_failure_err`] computed from the ORIGINAL entry (keeping the
/// PR-4 contract: gone/replaced tables answer `no_such_table`, never
/// `internal`). Shared by the lookup and fan-out retry paths so their
/// swap semantics cannot drift.
fn resized_entry(
    registry: &TableRegistry,
    entry: &Arc<TableEntry>,
) -> Option<Arc<TableEntry>> {
    // thin-pointer compare: Arc::ptr_eq on dyn Arcs may also compare
    // vtable metadata, which can differ across codegen units for the
    // same object -- strip to the data address
    let backend_addr =
        |e: &Arc<TableEntry>| Arc::as_ptr(&e.backend) as *const ();
    match registry.get(&entry.name) {
        Some(cur)
            if !Arc::ptr_eq(&cur, entry)
                && backend_addr(&cur) == backend_addr(entry) =>
        {
            Some(cur)
        }
        _ => None,
    }
}

/// Typed rejection for a lookup that kept losing its entry to
/// back-to-back `set_replicas` swaps: the table is alive and healthy,
/// so the code says "resized, retry" -- answering `no_such_table`
/// would wrongly tell routing clients to drop a live table.
fn resize_flap_err(name: &str) -> WireError {
    WireError::Rejected {
        code: "resized".into(),
        message: format!(
            "table {name:?} was resized (set_replicas) repeatedly while \
             the lookup was in flight; retry"),
    }
}

/// Resolve the request's table, validate ids, route through the batcher
/// shards, and encode the response for one lookup op. Like every op
/// handler, writes to a `dyn Write` sink -- a `TcpStream` on the
/// threaded plane, a per-connection ordered output buffer on the event
/// plane -- so both planes serve byte-identical responses.
fn lookup_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
    version: u64,
    binary: bool,
) -> Result<(), WireError> {
    let op = if binary { "lookup_bin" } else { "lookup" };
    let reject = |stream: &mut dyn Write, e: &WireError| -> Result<(), WireError> {
        let frame = annotated_err_frame(registry, e);
        if binary {
            write_bin_reject_frame(stream, version, &frame)
        } else {
            write_frame(stream, &frame.to_string())
        }
    };
    let named = if version >= 2 {
        j.get("table").and_then(|v| v.as_str())
    } else {
        None // v1 frames always hit the default table
    };
    let entry = match registry.resolve(named) {
        Ok(e) => e,
        Err(e) => return reject(stream, &e),
    };
    // malformed or out-of-range ids -> rejection, never clamped
    let ids = match validate_ids(&entry, j, op) {
        Ok(ids) => ids,
        Err(e) => return reject(stream, &e),
    };
    let d = entry.backend.d();
    // A live `set_replicas` resize swaps the table to a fresh entry and
    // closes the old entry's queues; a lookup caught in that window gets
    // a failed wait. The table is alive and the backend identical, so
    // retry against the CURRENT entry (bounded -- an operator flipping
    // replicas in a tight loop must not pin this request forever; the
    // exhaustion answer is a typed retryable "resized", NOT
    // no_such_table for a live table). Every other failure keeps the
    // PR-4 semantics: an explicit error, never ok:true with a short
    // vector list -- unloaded/evicted/demoted mid-flight answers
    // no_such_table; a still-registered same entry is the bug path.
    let mut entry = entry;
    let mut tries = 0;
    let ans: Answer = loop {
        match entry.lookup(&ids) {
            Some(a) => break a,
            None => match resized_entry(registry, &entry) {
                Some(cur) if tries < 3 => {
                    tries += 1;
                    // the replay re-counts in begin_lookup; keep
                    // `requests` an exact per-client-request total
                    entry.stats.requests.fetch_sub(1, Ordering::Relaxed);
                    entry = cur; // resized: same table, new shards
                }
                Some(_) => {
                    return reject(stream, &resize_flap_err(&entry.name))
                }
                None => return reject(
                    stream, &batch_failure_err(registry, &entry)),
            },
        }
    };
    let flat = ans.as_slice();
    debug_assert_eq!(flat.len(), ids.len() * d);
    if binary {
        match write_bin_rows(stream, version, ids.len(), d, flat) {
            Err(e @ WireError::Rejected { .. }) if version >= 2 => {
                // v2 can still answer typed (nothing written yet on the
                // too_large path); v1 has no in-band way, so propagate
                // and drop the connection loudly
                reject(stream, &e)
            }
            other => other,
        }
    } else {
        // Same frame-cap discipline as the binary path, applied BEFORE
        // materializing the response. Rust float Display never uses
        // scientific notation, so a shortest-roundtrip f32 can reach
        // ~60 chars for subnormals; 64 bytes per value (incl separators)
        // is a safe ceiling. The bound guarantees the encoded frame
        // stays under what the peer's read_frame accepts -- reject typed
        // instead of building a string the client would refuse
        // (desyncing the connection).
        if flat.len() as u64 * 64 > protocol::MAX_FRAME as u64 {
            return reject(stream, &WireError::Rejected {
                code: "too_large".into(),
                message: format!(
                    "{} rows x d={d} exceeds the JSON frame cap; use \
                     lookup_bin or smaller batches", ids.len()),
            });
        }
        let arr = Json::arr(
            flat.chunks(d.max(1))
                .map(|row| Json::arr(
                    row.iter().map(|&x| Json::num(x as f64)).collect()))
                .collect(),
        );
        write_frame(stream, &Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("table", Json::str(entry.name.as_str())),
            ("n", Json::num(ids.len() as f64)),
            ("d", Json::num(d as f64)),
            ("vectors", arr),
        ]).to_string())
    }
}

/// `lookup_fanout` (v2 only): resolve and validate EVERY `(table, ids)`
/// pair, queue all sub-lookups on their tables' batcher shards, then
/// assemble one multi-section binary response in request order. The op
/// is all-or-nothing -- any unknown table or bad id rejects the whole
/// frame BEFORE anything is queued, so a rejection never leaves half
/// the sections in flight.
fn fanout_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
    version: u64,
) -> Result<(), WireError> {
    let streamed = wants_stream(j);
    // Settle the budget before EVERY response (answer or rejection):
    // if a section promoted under frame-wide protection, the registry
    // may be softly over budget once the frame no longer needs all of
    // its tables resident. Settling BEFORE the response bytes keeps
    // the observable rule simple: when a fan-out answer arrives, the
    // registry is back within budget.
    let promotes_before = registry.promote_count();
    let settle = |registry: &TableRegistry| {
        if registry.promote_count() != promotes_before {
            registry.enforce_budget();
        }
    };
    let reject = |stream: &mut dyn Write, e: &WireError| -> Result<(), WireError> {
        settle(registry);
        write_bin_reject_frame(stream, version, &annotated_err_frame(registry, e))
    };
    let Some(queries) = j.get("queries").and_then(|v| v.as_arr()) else {
        return reject(stream, &WireError::Rejected {
            code: "bad_request".into(),
            message: "lookup_fanout needs a queries array of {table, ids}".into(),
        });
    };
    // Amplification cap, BEFORE any resolve/queue work: a 64 MiB frame
    // packed with ~12-byte `{"ids":[]}` sections would otherwise fan a
    // single request out into millions of batcher round trips. The
    // section count is the cost driver (per-section tickets + condvar
    // waits), so it gets its own bound beside the byte caps.
    if queries.len() > MAX_FANOUT_SECTIONS {
        return reject(stream, &WireError::Rejected {
            code: "too_large".into(),
            message: format!(
                "lookup_fanout with {} sections exceeds the cap \
                 ({MAX_FANOUT_SECTIONS}); split the request", queries.len()),
        });
    }
    // Every table named by the frame is protected from eviction while
    // the frame's promotions run: under a tight budget, section N's
    // transparent reload could otherwise demote section M's table and
    // every retry would re-play the same promote/evict cycle, never
    // completing. The registry may go softly over budget for the
    // frame; `settle` re-enforces before the frame is answered.
    // (Sections routed to the DEFAULT table need no entry here -- the
    // default is always pinned.)
    let protect: Vec<&str> = queries
        .iter()
        .filter_map(|q| q.get("table").and_then(|v| v.as_str()))
        .collect();
    let mut parts: Vec<(Arc<TableEntry>, Vec<usize>)> =
        Vec::with_capacity(queries.len());
    for q in queries {
        let named = q.get("table").and_then(|v| v.as_str());
        let entry = match registry.resolve_protected(named, &protect) {
            Ok(e) => e,
            Err(e) => return reject(stream, &e),
        };
        // same strict validation as lookup/lookup_bin, shared helper
        let ids = match validate_ids(&entry, q, "lookup_fanout") {
            Ok(ids) => ids,
            Err(e) => return reject(stream, &e),
        };
        parts.push((entry, ids));
    }
    // frame-cap discipline BEFORE queueing, same as every binary path:
    // nothing has been written or enqueued when this rejects. A
    // streamed response has no single-frame cap -- only the u64
    // overflow check applies (an absurd request, but it must reject
    // typed, not wrap).
    let dims: Vec<(usize, usize)> = parts
        .iter()
        .map(|(e, ids)| (ids.len(), e.backend.d()))
        .collect();
    if sections_payload_bytes(&dims)
        .filter(|&b| streamed || b <= protocol::MAX_FRAME as u64)
        .is_none()
    {
        return reject(stream, &WireError::Rejected {
            code: "too_large".into(),
            message: format!(
                "fan-out response over {} sections exceeds the frame cap; \
                 split the request or set \"stream\": true", parts.len()),
        });
    }
    // queue EVERY table's sub-lookups before waiting on any, so the
    // tables' batchers (and their shards) reconstruct concurrently --
    // this is what makes the fan-out one round trip instead of a loop
    let mut tries = 0;
    let answers: Vec<Answer> = loop {
        let tickets: Vec<_> =
            parts.iter().map(|(e, ids)| e.begin_lookup(ids)).collect();
        let mut answers: Vec<Answer> = Vec::with_capacity(tickets.len());
        let mut failed: Option<usize> = None;
        for (k, t) in tickets.into_iter().enumerate() {
            match t.wait() {
                Some(a) => answers.push(a),
                // remember which section failed, keep draining the rest
                None => failed = failed.or(Some(k)),
            }
        }
        let Some(k) = failed else { break answers };
        // Was the FAILED section's failure a live set_replicas swap?
        // Decide from section k's ORIGINAL entry, before any refresh,
        // so the rejection code keeps the PR-4 contract: a table
        // unloaded/demoted mid-flight answers no_such_table (annotated)
        // for the whole frame, never `internal`. Only a swap to an
        // entry over the SAME backend Arc (a genuine resize) replays
        // the frame, all-or-nothing, bounded (a flapping operator must
        // not pin this frame forever).
        if resized_entry(registry, &parts[k].0).is_none() {
            return reject(stream, &batch_failure_err(registry, &parts[k].0));
        }
        tries += 1;
        if tries >= 4 {
            return reject(stream, &resize_flap_err(&parts[k].0.name));
        }
        // Undo this round's request counts FIRST, on the entries that
        // were actually begun (a same-name reload carries FRESH stats,
        // so decrementing after a refresh would underflow the new
        // entry's counter and strand a phantom count on the old one),
        // THEN refresh every swapped section -- section k included. A
        // section whose table vanished is left as-is: its replay fails
        // and the next round rejects with THAT section's own
        // (no_such_table) error.
        for (e, _) in parts.iter_mut() {
            e.stats.requests.fetch_sub(1, Ordering::Relaxed);
            if let Some(cur) = resized_entry(registry, e) {
                *e = cur;
            }
        }
    };
    registry.note_fanout();
    let sections: Vec<(usize, usize, &[f32])> = parts
        .iter()
        .zip(&answers)
        .map(|((e, ids), a)| (ids.len(), e.backend.d(), a.as_slice()))
        .collect();
    settle(registry);
    if streamed {
        // same section layout as the single frame, chunked: assembled
        // client-side bytes are identical to the unstreamed response
        let payload = match bin_sections_payload(&sections) {
            Ok(p) => p,
            Err(e) => return reject(stream, &e),
        };
        return write_stream_payload(stream, &payload);
    }
    write_bin_sections(stream, &sections)
}

/// Whether the request opted into the chunked streaming response
/// encoding (`"stream": true`). Only meaningful on the v2-only ops
/// that support it (`lookup_fanout`, `topk`); any other value of the
/// field -- absent, false, non-boolean -- means the ordinary
/// single-frame response.
fn wants_stream(j: &Json) -> bool {
    j.get("stream").and_then(|v| v.as_bool()) == Some(true)
}

/// Resolve a `score`/`topk` request's query vector: an explicit
/// `"query"` array (strictly finite, width-checked against the table's
/// `d`) or `"query_id"` naming a row of the SAME table, reconstructed
/// server-side -- "nearest neighbours of item X" without the client
/// ever holding a vector. Exactly one of the two must be present.
fn query_for(entry: &TableEntry, j: &Json, op: &str) -> Result<Vec<f32>, WireError> {
    let d = entry.backend.d();
    if let Some(q) = parse_query(j, op)? {
        if q.len() != d {
            return Err(WireError::Rejected {
                code: "width_mismatch".into(),
                message: format!(
                    "{op} query has {} values but table {:?} has d={d}",
                    q.len(), entry.name),
            });
        }
        return Ok(q);
    }
    match j.get("query_id") {
        Some(v) => {
            let Some(id) = v.as_usize() else {
                return Err(WireError::Malformed(format!(
                    "{op} query_id must be a non-negative integer")));
            };
            let vocab = entry.backend.vocab();
            if id >= vocab {
                return Err(WireError::Rejected {
                    code: "bad_ids".into(),
                    message: format!(
                        "query_id {id} out of range [0, {vocab}) for \
                         table {:?}", entry.name),
                });
            }
            let mut row = vec![0.0f32; d];
            entry.backend.reconstruct_rows_into(&[id], &mut row);
            Ok(row)
        }
        None => Err(WireError::Rejected {
            code: "bad_request".into(),
            message: format!("{op} needs a query array or query_id"),
        }),
    }
}

/// The typed rejection for a backend kind without the scoring
/// capability ([`EmbeddingBackend::scorer`](crate::backend::EmbeddingBackend::scorer)
/// returned `None`): the client learns it must fall back to
/// lookup-then-score client-side, instead of getting a misleading
/// `internal`.
fn score_unsupported_err(entry: &TableEntry) -> WireError {
    WireError::Rejected {
        code: "score_unsupported".into(),
        message: format!(
            "table {:?} (kind {:?}) has no compute-on-codes scorer; \
             use lookup and score client-side",
            entry.name, entry.backend.kind()),
    }
}

/// `score` (v2 only): dot-product scores for an explicit candidate id
/// list against a query, computed on the table's compressed
/// representation (ADC lookup tables for `dpq`/`scalar_quant`, the
/// pool-sharded exact path for `dense`/`low_rank`). Resolution goes
/// through [`TableRegistry::resolve`] so TTL touch, LRU stamping,
/// transparent spill promotion and the memory budget apply exactly as
/// they do to `lookup`; the scan itself runs on this connection thread
/// over the shared backend, tracked against the least-loaded-replica
/// signal via [`TableEntry::begin_score`].
fn score_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
) -> Result<(), WireError> {
    let reject = |stream: &mut dyn Write, e: &WireError| -> Result<(), WireError> {
        write_frame(stream, &annotated_err_frame(registry, e).to_string())
    };
    let named = j.get("table").and_then(|v| v.as_str());
    let entry = match registry.resolve(named) {
        Ok(e) => e,
        Err(e) => return reject(stream, &e),
    };
    entry.stats.score_requests.fetch_add(1, Ordering::Relaxed);
    let query = match query_for(&entry, j, "score") {
        Ok(q) => q,
        Err(e) => return reject(stream, &e),
    };
    let ids = match validate_ids(&entry, j, "score") {
        Ok(ids) => ids,
        Err(e) => return reject(stream, &e),
    };
    // same JSON frame-cap discipline as lookup: bound the encoded size
    // BEFORE computing, typed instead of desyncing the connection
    if ids.len() as u64 * 64 > protocol::MAX_FRAME as u64 {
        return reject(stream, &WireError::Rejected {
            code: "too_large".into(),
            message: format!(
                "{} candidate scores exceed the JSON frame cap; split \
                 the id list", ids.len()),
        });
    }
    let Some(sb) = entry.backend.scorer() else {
        return reject(stream, &score_unsupported_err(&entry));
    };
    let _depth = entry.begin_score();
    let t0 = std::time::Instant::now();
    let base = sb.query_scorer(&query);
    // Where the backend scores by exact reconstruction anyway, hot
    // candidates are served from the row cache instead of a code-walk.
    // Bit-identical: cached rows are verbatim copies of deterministic
    // reconstructions, so the dot products cannot differ. The ADC
    // ("lut") path is NEVER substituted -- its scores are computed on
    // codes, not rows, and swapping paths would change bits.
    let cached;
    let scorer: &dyn crate::scoring::QueryScorer =
        if base.path() == "exact" && entry.row_cache.enabled() {
            cached = crate::scoring::ExactScorer::with_rows(
                &*entry.backend, &query, &*entry.row_cache);
            &cached
        } else {
            &*base
        };
    let mut scores = vec![0.0f32; ids.len()];
    crate::scoring::score_into(scorer, &ids, &mut scores);
    entry.stats.record_score_secs(t0.elapsed().as_secs_f64());
    write_frame(stream, &Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("table", Json::str(entry.name.as_str())),
        ("n", Json::num(ids.len() as f64)),
        ("path", Json::str(scorer.path())),
        ("scores", Json::arr(
            scores.iter().map(|&s| Json::num(s as f64)).collect())),
    ]).to_string())
}

/// `topk` (v2 only): the k most-similar rows to a query over the whole
/// table (or `lo..hi` when given), computed on codes, best first, ties
/// broken by ascending id -- bit-identical at every thread, shard and
/// replica count. Shares the resolution/query/accounting path with
/// [`score_op`].
fn topk_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
) -> Result<(), WireError> {
    // A streamed topk's client reads the binary continuation channel,
    // so its rejections must arrive on that channel too (the u32::MAX
    // sentinel + JSON error frame, exactly like binary lookups) -- a
    // bare JSON frame would desync the client's payload decoder.
    let streamed = wants_stream(j);
    let reject = |stream: &mut dyn Write, e: &WireError| -> Result<(), WireError> {
        let frame = annotated_err_frame(registry, e);
        if streamed {
            write_bin_reject_frame(stream, VERSION, &frame)
        } else {
            write_frame(stream, &frame.to_string())
        }
    };
    let named = j.get("table").and_then(|v| v.as_str());
    let entry = match registry.resolve(named) {
        Ok(e) => e,
        Err(e) => return reject(stream, &e),
    };
    entry.stats.topk_requests.fetch_add(1, Ordering::Relaxed);
    let query = match query_for(&entry, j, "topk") {
        Ok(q) => q,
        Err(e) => return reject(stream, &e),
    };
    let vocab = entry.backend.vocab();
    // k = 0 asks for nothing and k > vocab asks for more than exists:
    // both are caller bugs worth a typed answer, not a silent clamp
    let k = match j.get("k").and_then(|v| v.as_usize()) {
        Some(k) if k >= 1 && k <= vocab => k,
        Some(k) => {
            return reject(stream, &WireError::Rejected {
                code: "bad_k".into(),
                message: format!(
                    "k={k} out of range [1, {vocab}] for table {:?}",
                    entry.name),
            })
        }
        None => {
            return reject(stream, &WireError::Rejected {
                code: "bad_request".into(),
                message: "topk needs a positive integer k".into(),
            })
        }
    };
    // optional candidate restriction: both bounds or neither, and the
    // window must lie inside the id space (empty lo==hi is legal)
    let (lo, hi) = match (j.get("lo"), j.get("hi")) {
        (None, None) => (0, vocab),
        (Some(l), Some(h)) => match (l.as_usize(), h.as_usize()) {
            (Some(lo), Some(hi)) if lo <= hi && hi <= vocab => (lo, hi),
            _ => {
                return reject(stream, &WireError::Rejected {
                    code: "bad_range".into(),
                    message: format!(
                        "topk range must satisfy lo <= hi <= {vocab}"),
                })
            }
        },
        _ => {
            return reject(stream, &WireError::Rejected {
                code: "bad_range".into(),
                message: "topk range needs both lo and hi (or neither)".into(),
            })
        }
    };
    // JSON frame-cap discipline, SKIPPED for streamed responses: the
    // chunked binary encoding has no single-frame cap, which is what
    // lets a full-vocab topk stream instead of rejecting here.
    if !streamed && k as u64 * 2 * 64 > protocol::MAX_FRAME as u64 {
        return reject(stream, &WireError::Rejected {
            code: "too_large".into(),
            message: format!(
                "top-{k} response exceeds the JSON frame cap; lower k \
                 or set \"stream\": true"),
        });
    }
    let Some(sb) = entry.backend.scorer() else {
        return reject(stream, &score_unsupported_err(&entry));
    };
    let _depth = entry.begin_score();
    let t0 = std::time::Instant::now();
    let base = sb.query_scorer(&query);
    // same cache substitution rule as `score_op`: exact path only
    let cached;
    let scorer: &dyn crate::scoring::QueryScorer =
        if base.path() == "exact" && entry.row_cache.enabled() {
            cached = crate::scoring::ExactScorer::with_rows(
                &*entry.backend, &query, &*entry.row_cache);
            &cached
        } else {
            &*base
        };
    let best = crate::scoring::topk(scorer, lo, hi, k);
    entry.stats.record_score_secs(t0.elapsed().as_secs_f64());
    if streamed {
        // binary columnar payload: u64 n, then n u64 LE ids, then n
        // f32 LE scores -- same best-first order (ties ascending id)
        // as the JSON response, decoded by `Client::topk_stream`
        let n = best.len();
        let mut payload = Vec::with_capacity(8 + n * 12);
        payload.extend_from_slice(&(n as u64).to_le_bytes());
        for c in &best {
            payload.extend_from_slice(&(c.id as u64).to_le_bytes());
        }
        for c in &best {
            payload.extend_from_slice(&c.score.to_le_bytes());
        }
        return write_stream_payload(stream, &payload);
    }
    write_frame(stream, &Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("table", Json::str(entry.name.as_str())),
        ("k", Json::num(best.len() as f64)),
        ("path", Json::str(scorer.path())),
        ("ids", Json::arr(
            best.iter().map(|c| Json::num(c.id as f64)).collect())),
        ("scores", Json::arr(
            best.iter().map(|c| Json::num(c.score as f64)).collect())),
    ]).to_string())
}

/// `snapshot` (v2 only): serialize the whole registry into a
/// server-side directory and answer with the manifest path.
fn snapshot_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
) -> Result<(), WireError> {
    let Some(dir) = j.get("dir").and_then(|v| v.as_str()) else {
        return write_frame(stream, &err_obj(
            "bad_request", "snapshot needs dir", vec![]).to_string());
    };
    match registry.snapshot(std::path::Path::new(dir)) {
        Ok(manifest) => write_frame(stream, &Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("manifest", Json::str(manifest.to_string_lossy().as_ref())),
            ("tables", Json::num(registry.len() as f64)),
        ]).to_string()),
        Err(e) => write_frame(stream, &err_frame(&e).to_string()),
    }
}

/// Counters + ring-buffer latency percentiles for one table's [`Stats`]
/// (resident tables and spilled tables share the shape -- counters ride
/// across the spill tier).
fn stats_pairs(stats: &Stats) -> Vec<(&'static str, Json)> {
    let mut pairs = vec![
        ("requests",
         Json::num(stats.requests.load(Ordering::Relaxed) as f64)),
        ("ids_served",
         Json::num(stats.ids_served.load(Ordering::Relaxed) as f64)),
        ("batches",
         Json::num(stats.batches.load(Ordering::Relaxed) as f64)),
        ("score_requests",
         Json::num(stats.score_requests.load(Ordering::Relaxed) as f64)),
        ("topk_requests",
         Json::num(stats.topk_requests.load(Ordering::Relaxed) as f64)),
        ("cache_hits",
         Json::num(stats.cache_hits.load(Ordering::Relaxed) as f64)),
        ("cache_misses",
         Json::num(stats.cache_misses.load(Ordering::Relaxed) as f64)),
    ];
    if let Some(rate) = stats.cache_hit_rate() {
        pairs.push(("cache_hit_rate", Json::num(rate)));
    }
    if let Some((p50, p99)) = stats.batch_latency() {
        pairs.push(("batch_p50_s", Json::num(p50)));
        pairs.push(("batch_p99_s", Json::num(p99)));
    }
    if let Some((p50, p99)) = stats.score_latency() {
        pairs.push(("score_p50_s", Json::num(p50)));
        pairs.push(("score_p99_s", Json::num(p99)));
    }
    pairs
}

/// The per-table stats object for a spilled table: residency (probed
/// against the spill tier, so an out-of-band deleted artifact reports
/// `"lost"` here instead of surprising the next lookup), the recorded
/// shape, and the carried-over serving counters.
fn spilled_stats_pairs(
    registry: &TableRegistry,
    s: &Arc<SpilledTable>,
) -> Vec<(&'static str, Json)> {
    let mut residency = registry.probe_spilled(s);
    if residency == Residency::Lost {
        // A promotion may have consumed the artifact after this slot
        // was fetched: probing the STALE slot then looks "lost" for a
        // table that is resident and serving. Only alarm when the map
        // still holds this very slot; otherwise report the snapshot's
        // stale-but-true "spilled".
        match registry.slot_of(s.name()) {
            Some(registry::Slot::Spilled(cur)) if Arc::ptr_eq(&cur, s) => {}
            _ => residency = Residency::Spilled,
        }
    }
    let mut pairs = vec![
        ("residency", Json::str(residency.as_str())),
        ("kind", Json::str(s.kind())),
        ("vocab", Json::num(s.vocab() as f64)),
        ("d", Json::num(s.d() as f64)),
        ("storage_bits", Json::num(s.storage_bits() as f64)),
        ("spilled_bytes", Json::num(s.spilled_bytes() as f64)),
        ("spill_file", Json::str(s.file())),
        // serving config a hydrating peer rebuilds the slot with
        ("replicas", Json::num(s.replicas() as f64)),
        ("row_cache", Json::num(s.row_cache_bytes() as f64)),
    ];
    // content digest: what `fetch_artifact` serves this artifact under;
    // absent for legacy slots that have not been re-hashed yet
    if let Some((hex, bytes)) = s.digest() {
        pairs.push(("sha256", Json::str(hex.as_str())));
        pairs.push(("bytes", Json::num(bytes as f64)));
    }
    pairs.extend(stats_pairs(s.stats()));
    pairs
}

fn stats_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
    version: u64,
) -> Result<(), WireError> {
    if version >= 2 {
        if let Some(name) = j.get("table").and_then(|v| v.as_str()) {
            // One table, flat, from ONE consistent slot read (separate
            // resident/spilled reads could race a promotion and answer
            // no_such_table for a live table). NOT `resolve`: a
            // monitoring poll must not stamp the LRU clock (dashboards
            // would corrupt the eviction order) nor promote a spilled
            // table (polling must not defeat the operator's demote).
            let mut pairs = vec![("ok", Json::Bool(true))];
            match registry.slot_of(name) {
                Some(registry::Slot::Resident(entry)) => {
                    pairs.push(("table", Json::str(entry.name.as_str())));
                    pairs.push(("residency",
                                Json::str(Residency::Resident.as_str())));
                    pairs.push(("replicas",
                                Json::num(entry.replica_count() as f64)));
                    pairs.push(("replica", entry.replica_stats_json()));
                    pairs.push(("row_cache_cap_bytes",
                                Json::num(entry.row_cache.cap_bytes() as f64)));
                    pairs.push(("row_cache_bytes",
                                Json::num(entry.row_cache.bytes() as f64)));
                    pairs.extend(stats_pairs(&entry.stats));
                }
                Some(registry::Slot::Spilled(s)) => {
                    pairs.push(("table", Json::str(s.name())));
                    pairs.extend(spilled_stats_pairs(registry, &s));
                }
                None => {
                    let e = WireError::NoSuchTable(name.to_string());
                    return write_frame(
                        stream, &annotated_err_frame(registry, &e).to_string());
                }
            }
            return write_frame(stream, &Json::obj(pairs).to_string());
        }
    }
    // aggregate view: v1-compatible flat totals plus a per-table map
    // covering BOTH tiers (spilled tables stay stats-visible). ONE map
    // snapshot feeds totals and the per-table map, so a table demoted
    // mid-poll is never counted in both tiers.
    let slots = registry.snapshot_slots();
    let (mut requests, mut ids_served, mut batches) = (0u64, 0u64, 0u64);
    for (_, slot) in &slots {
        let stats = match slot {
            registry::Slot::Resident(e) => &*e.stats,
            registry::Slot::Spilled(s) => s.stats(),
        };
        requests += stats.requests.load(Ordering::Relaxed);
        ids_served += stats.ids_served.load(Ordering::Relaxed);
        batches += stats.batches.load(Ordering::Relaxed);
    }
    let per_table = Json::Obj(
        slots
            .iter()
            .map(|(name, slot)| {
                let pairs = match slot {
                    registry::Slot::Resident(e) => {
                        let mut pairs = vec![
                            ("residency",
                             Json::str(Residency::Resident.as_str())),
                            ("replicas",
                             Json::num(e.replica_count() as f64)),
                            ("replica", e.replica_stats_json()),
                            ("row_cache_cap_bytes",
                             Json::num(e.row_cache.cap_bytes() as f64)),
                            ("row_cache_bytes",
                             Json::num(e.row_cache.bytes() as f64)),
                        ];
                        pairs.extend(stats_pairs(&e.stats));
                        pairs
                    }
                    registry::Slot::Spilled(s) => {
                        spilled_stats_pairs(registry, s)
                    }
                };
                (name.clone(), Json::obj(pairs))
            })
            .collect(),
    );
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("requests", Json::num(requests as f64)),
        ("ids_served", Json::num(ids_served as f64)),
        ("batches", Json::num(batches as f64)),
        ("fanout_requests", Json::num(registry.fanout_count() as f64)),
        // memory-pressure telemetry: resident total, optional budget,
        // eviction count, and which tables are currently evicted
        ("resident_bytes", Json::num(registry.resident_bytes() as f64)),
        ("evictions", Json::num(registry.eviction_count() as f64)),
        // TTL-caused expirations, attributed separately from budget
        // evictions ("whichever fires first wins" is auditable)
        ("ttl_demotions", Json::num(registry.ttl_demotion_count() as f64)),
        // spill-tier telemetry: demotions, transparent reloads, and the
        // reload-latency ring operators size cold-start SLOs from
        ("spills", Json::num(registry.spill_count() as f64)),
        ("promotes", Json::num(registry.promote_count() as f64)),
        // failed spill.json write-then-renames: nonzero means the
        // published manifest drifted from the registry until a later
        // transition rewrote it (a climbing count = sick spill dir)
        ("spill_manifest_write_failures",
         Json::num(registry.spill_manifest_write_failures() as f64)),
    ];
    // connection-plane counters (accept loop + per-connection threads);
    // always present so dashboards need no key-existence probing
    let cs = registry.conn_stats();
    for (key, counter) in [
        ("conns_open", &cs.conns_open),
        ("conns_total", &cs.conns_total),
        ("busy_rejections", &cs.busy_rejections),
        ("conn_timeouts", &cs.conn_timeouts),
        ("handler_panics", &cs.handler_panics),
    ] {
        pairs.push((key, Json::num(counter.load(Ordering::Relaxed) as f64)));
    }
    if let Some((p50, p99)) = registry.promote_latency() {
        pairs.push(("promote_p50_s", Json::num(p50)));
        pairs.push(("promote_p99_s", Json::num(p99)));
    }
    if let Some(b) = registry.config().mem_budget_bytes {
        pairs.push(("mem_budget_bytes", Json::num(b as f64)));
    }
    if let Some(t) = registry.config().ttl_secs {
        pairs.push(("ttl_secs", Json::num(t as f64)));
    }
    let evicted = registry.evicted_tables();
    if !evicted.is_empty() {
        pairs.push(("evicted", Json::Obj(
            evicted
                .into_iter()
                .map(|(name, count)| (name, Json::num(count as f64)))
                .collect(),
        )));
    }
    pairs.push(("tables", per_table));
    write_frame(stream, &Json::obj(pairs).to_string())
}

fn tables_op(stream: &mut dyn Write, registry: &TableRegistry) -> Result<(), WireError> {
    let mut pairs = vec![("ok", Json::Bool(true)), ("v", Json::num(VERSION as f64))];
    let default = registry.default_name();
    if let Some(d) = &default {
        pairs.push(("default", Json::str(d.as_str())));
    }
    // one consistent slot snapshot: a table demoted mid-request must
    // appear in exactly one of the two listings
    let slots = registry.snapshot_slots();
    pairs.push(("tables", Json::arr(
        slots
            .iter()
            .filter_map(|(_, s)| match s {
                registry::Slot::Resident(e) => Some(e.desc_json()),
                registry::Slot::Spilled(_) => None,
            })
            .collect())));
    // spilled tables are still registered -- list their names so an
    // operator reading `tables` sees the whole registry (full spill
    // detail lives in `stats`)
    let spilled: Vec<Json> = slots
        .iter()
        .filter_map(|(_, s)| match s {
            registry::Slot::Spilled(sp) => Some(Json::str(sp.name())),
            registry::Slot::Resident(_) => None,
        })
        .collect();
    if !spilled.is_empty() {
        pairs.push(("spilled", Json::arr(spilled)));
    }
    write_frame(stream, &Json::obj(pairs).to_string())
}

/// `demote` (v2 only): explicitly spill a resident table to the
/// `--spill-dir` tier. The next lookup transparently reloads it.
fn demote_op(stream: &mut dyn Write, registry: &TableRegistry, j: &Json) -> Result<(), WireError> {
    let Some(name) = j.get("table").and_then(|v| v.as_str()) else {
        return write_frame(stream, &err_obj(
            "bad_request", "demote needs table", vec![]).to_string());
    };
    match registry.demote(name) {
        Ok(slot) => write_frame(stream, &Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("table", Json::str(slot.name())),
            ("residency", Json::str(Residency::Spilled.as_str())),
            ("file", Json::str(slot.file())),
            ("spilled_bytes", Json::num(slot.spilled_bytes() as f64)),
        ]).to_string()),
        Err(e) => write_frame(
            stream, &annotated_err_frame(registry, &e).to_string()),
    }
}

/// `set_replicas` (v2 only): live-resize a table's batcher-shard
/// replica count. A resident table is swapped in place (mid-traffic
/// lookups are transparently retried against the new entry); a spilled
/// table records the count for its next promotion.
fn set_replicas_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
) -> Result<(), WireError> {
    let (name, n) = match (
        j.get("table").and_then(|v| v.as_str()),
        j.get("replicas").and_then(|v| v.as_usize()),
    ) {
        (Some(name), Some(n)) => (name, n),
        _ => {
            return write_frame(stream, &err_obj(
                "bad_request",
                "set_replicas needs table and a non-negative integer replicas",
                vec![]).to_string())
        }
    };
    match registry.set_replicas(name, n) {
        Ok(n) => {
            let residency = registry
                .residency(name)
                .unwrap_or(Residency::Resident);
            write_frame(stream, &Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("table", Json::str(name)),
                ("replicas", Json::num(n as f64)),
                ("residency", Json::str(residency.as_str())),
            ]).to_string())
        }
        Err(e) => write_frame(
            stream, &annotated_err_frame(registry, &e).to_string()),
    }
}

/// `set_row_cache` (v2 only): resize a table's hot-row cache byte cap
/// in place (0 disables and drops every cached row). A resident table
/// trims immediately and re-enforces the memory budget (cache capacity
/// counts against `--mem-budget`); a spilled table records the cap for
/// its next promotion.
fn set_row_cache_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
) -> Result<(), WireError> {
    let (name, bytes) = match (
        j.get("table").and_then(|v| v.as_str()),
        j.get("bytes").and_then(|v| v.as_usize()),
    ) {
        (Some(name), Some(bytes)) => (name, bytes as u64),
        _ => {
            return write_frame(stream, &err_obj(
                "bad_request",
                "set_row_cache needs table and a non-negative integer bytes",
                vec![]).to_string())
        }
    };
    match registry.set_row_cache(name, bytes) {
        Ok(cap) => {
            let residency = registry
                .residency(name)
                .unwrap_or(Residency::Resident);
            write_frame(stream, &Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("table", Json::str(name)),
                ("row_cache_cap_bytes", Json::num(cap as f64)),
                ("residency", Json::str(residency.as_str())),
            ]).to_string())
        }
        Err(e) => write_frame(
            stream, &annotated_err_frame(registry, &e).to_string()),
    }
}

fn load_op(stream: &mut dyn Write, registry: &TableRegistry, j: &Json) -> Result<(), WireError> {
    let (name, path) = match (
        j.get("table").and_then(|v| v.as_str()),
        j.get("path").and_then(|v| v.as_str()),
    ) {
        (Some(n), Some(p)) => (n, p),
        _ => {
            return write_frame(stream, &err_obj(
                "bad_request", "load needs table and path", vec![]).to_string())
        }
    };
    match registry.load_dpq(name, std::path::Path::new(path)) {
        Ok(entry) => {
            let mut pairs = vec![("ok", Json::Bool(true)),
                                 ("table", entry.desc_json())];
            let default = registry.default_name();
            if let Some(d) = &default {
                pairs.push(("default", Json::str(d.as_str())));
            }
            write_frame(stream, &Json::obj(pairs).to_string())
        }
        Err(e) => write_frame(stream, &err_frame(&e).to_string()),
    }
}

fn unload_op(stream: &mut dyn Write, registry: &TableRegistry, j: &Json) -> Result<(), WireError> {
    let Some(name) = j.get("table").and_then(|v| v.as_str()) else {
        return write_frame(stream, &err_obj(
            "bad_request", "unload needs table", vec![]).to_string());
    };
    match registry.unload(name) {
        // the outcome makes the default-table hand-off explicit on the
        // wire: unloading the default re-elects (and names) a new one
        Ok(out) => {
            let mut pairs = vec![
                ("ok", Json::Bool(true)),
                ("was_default", Json::Bool(out.was_default)),
            ];
            if let Some(d) = &out.new_default {
                pairs.push(("default", Json::str(d.as_str())));
            }
            write_frame(stream, &Json::obj(pairs).to_string())
        }
        // annotated: unloading an already-evicted table answers
        // no_such_table with "evicted": true, same as the lookup paths
        Err(e) => write_frame(
            stream, &annotated_err_frame(registry, &e).to_string()),
    }
}

/// `fetch_artifact` (v2 only): serve a spilled artifact's raw bytes by
/// content digest, as a chunked stream (the artifact may exceed the
/// single-frame cap). The file is read and RE-HASHED before the first
/// chunk hits the socket -- the wire never carries bytes that do not
/// hash to the requested digest, even if the disk rotted after the
/// digest was recorded. The response is binary, so rejections use the
/// binary rejection channel (`u32::MAX` sentinel + typed JSON frame):
/// `not_found` for an unknown digest or one whose on-disk bytes no
/// longer match; `bad_digest` for a malformed digest string.
fn fetch_artifact_op(
    stream: &mut dyn Write,
    registry: &TableRegistry,
    j: &Json,
) -> Result<(), WireError> {
    let Some(sha) = j.get("sha256").and_then(|v| v.as_str()) else {
        return write_frame(stream, &err_obj(
            "bad_request", "fetch_artifact needs sha256", vec![]).to_string());
    };
    if !crate::util::sha256::is_hex_digest(sha) {
        return write_frame(stream, &err_obj(
            "bad_digest",
            &format!("{sha:?} is not a 64-char lowercase hex sha256"),
            vec![]).to_string());
    }
    let reject = |m: String| {
        err_obj("not_found", &m, vec![("sha256", Json::str(sha))])
    };
    let Some((_slot, path)) = registry.spilled_by_digest(sha) else {
        return write_bin_reject_frame(stream, 2, &reject(format!(
            "no spilled artifact with sha256 {sha}")));
    };
    // A concurrent promote may consume the file between the lookup and
    // this read; the re-hash also catches that (read error / mismatch),
    // so both degrade to the same typed not_found.
    let payload = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) => {
            return write_bin_reject_frame(stream, 2, &reject(format!(
                "artifact for sha256 {sha} is unreadable: {e}")));
        }
    };
    if crate::util::sha256::hex_digest(&payload) != sha {
        return write_bin_reject_frame(stream, 2, &reject(format!(
            "artifact on disk no longer hashes to {sha}; refusing to serve")));
    }
    write_stream_payload(stream, &payload)
}

/// Pull every spill artifact a peer advertises that this registry does
/// not already hold, verify each against its advertised digest **as it
/// lands**, and adopt the tables as `Spilled` slots -- a restarted or
/// newly added replica self-provisions over the wire with zero shared
/// disk (`repro hydrate`). The walk is `tables` (spilled names) then
/// per-table `stats` (kind, shape, file, digest, serving config);
/// names already registered locally are skipped, as are peer slots
/// with no advertised digest (legacy -- there is nothing to verify a
/// transfer against). Returns the number of tables adopted. Lives at
/// the server layer, not in [`TableRegistry`]: the registry stays
/// socket-free.
pub fn hydrate_from_peer(
    registry: &TableRegistry,
    client: &mut Client,
) -> Result<usize, WireError> {
    let Some(spill_dir) = registry.config().spill_dir.clone() else {
        return Err(WireError::Rejected {
            code: "spill_disabled".into(),
            message: "hydration needs a configured spill dir".into(),
        });
    };
    let hydrate_failed = |m: String| WireError::Rejected {
        code: "hydrate_failed".into(),
        message: m,
    };
    let mut adopted = 0usize;
    for name in client.spilled_tables()? {
        if registry.residency(&name).is_some() {
            continue; // already registered locally, either tier
        }
        let st = client.stats(Some(&name))?;
        let get_n = |k: &str| st.get(k).and_then(|v| v.as_usize());
        let get_s = |k: &str| st.get(k).and_then(|v| v.as_str());
        let (Some(kind), Some(file), Some(vocab), Some(d),
             Some(storage_bits)) =
            (get_s("kind"), get_s("spill_file"), get_n("vocab"),
             get_n("d"), get_n("storage_bits"))
        else {
            eprintln!(
                "hydrate: peer stats for table {name:?} are missing \
                 kind/file/shape; skipping");
            continue;
        };
        let (Some(sha), Some(bytes)) = (get_s("sha256"), get_n("bytes"))
        else {
            eprintln!(
                "hydrate: table {name:?} has no advertised digest (legacy \
                 peer slot); skipping");
            continue;
        };
        let payload = client.fetch_artifact(sha)?;
        // verify BEFORE anything touches disk: the advertised digest is
        // the contract, whatever the peer actually streamed
        if payload.len() != bytes
            || crate::util::sha256::hex_digest(&payload) != sha
        {
            return Err(hydrate_failed(format!(
                "artifact for table {name:?} does not hash to its \
                 advertised digest (expected {bytes} bytes sha256 {sha}, \
                 received {} bytes)", payload.len())));
        }
        // land write-then-rename (a `.tmp` suffix, so a crash orphan is
        // GC'd by the next startup's spill adoption)
        let tmp = spill_dir.join(format!(
            "{file}.hydrate-{}.tmp", std::process::id()));
        let landed = std::fs::write(&tmp, &payload)
            .and_then(|_| std::fs::rename(&tmp, spill_dir.join(file)));
        if let Err(e) = landed {
            let _ = std::fs::remove_file(&tmp);
            return Err(hydrate_failed(format!(
                "landing artifact {file:?} for table {name:?}: {e}")));
        }
        registry.adopt_spilled(SpillSeed {
            name: name.clone(),
            kind: kind.to_string(),
            file: file.to_string(),
            vocab,
            d,
            storage_bits,
            replicas: get_n("replicas").unwrap_or(1),
            row_cache: get_n("row_cache").unwrap_or(0) as u64,
            sha256: sha.to_string(),
            bytes: bytes as u64,
        })?;
        adopted += 1;
    }
    Ok(adopted)
}

fn handle_conn(
    mut stream: TcpStream,
    registry: Arc<TableRegistry>,
    stop: Arc<AtomicBool>,
) -> Result<(), WireError> {
    stream.set_nodelay(true)?;
    let timeout = registry.config().conn_timeout;
    // Responses get a write deadline even with --conn-timeout off: a
    // peer that never drains its receive buffer must not pin this
    // thread past the graceful-shutdown join.
    stream.set_write_timeout(Some(timeout.unwrap_or(WRITE_STALL_FALLBACK)))?;
    loop {
        let req = match read_frame_deadline(&mut stream, timeout, &stop) {
            Ok(FrameIn::Frame(r)) => r,
            // clean close at a frame boundary: peer EOF, or the server
            // is draining and this connection is idle
            Ok(FrameIn::Eof) | Ok(FrameIn::Stopped) => return Ok(()),
            Ok(FrameIn::TimedOut) => {
                // typed close: the peer (if it is listening at all)
                // learns WHY it was dropped. Best-effort -- a stalled
                // peer's receive window may be full too.
                registry
                    .conn_stats()
                    .conn_timeouts
                    .fetch_add(1, Ordering::Relaxed);
                let _ = write_frame(&mut stream, &err_obj(
                    "timeout",
                    "connection deadline (--conn-timeout) expired",
                    vec![]).to_string());
                return Ok(());
            }
            Ok(FrameIn::TooLarge(n)) => {
                // the payload was never read, so the stream cannot be
                // resynced: answer typed, then close
                let _ = write_frame(&mut stream, &err_obj(
                    "too_large",
                    &format!(
                        "frame of {n} bytes exceeds the {} byte cap",
                        protocol::MAX_FRAME),
                    vec![]).to_string());
                return Ok(());
            }
            Ok(FrameIn::NotUtf8(m)) => {
                // payload fully consumed -- the connection stays usable
                write_frame(&mut stream, &err_obj(
                    "malformed", &m, vec![]).to_string())?;
                continue;
            }
            Err(_) => return Ok(()), // peer vanished mid-frame
        };
        match process_frame(&mut stream, &registry, &stop, req.as_bytes())? {
            FrameOut::Continue => {}
            // shutdown acked, or the handler panicked (typed `internal`
            // already written): close this connection either way
            FrameOut::Shutdown | FrameOut::Closed => return Ok(()),
        }
    }
}

/// What processing one frame means for the connection that carried it.
pub(crate) enum FrameOut {
    /// Answered; keep reading frames.
    Continue,
    /// The frame was `shutdown`: ack written, stop flag raised. The
    /// connection closes once its response bytes have flushed.
    Shutdown,
    /// The handler panicked: a typed `internal` frame was written
    /// (best-effort) and the connection must close -- mid-op output
    /// may be half-written, so the stream cannot be trusted further.
    Closed,
}

/// Process ONE raw frame: utf-8 check, JSON parse, version
/// negotiation, then op dispatch under the panic-isolation barrier.
/// This is the single per-frame handler BOTH connection planes run --
/// the threaded plane from [`handle_conn`], the event plane from its
/// dispatch workers -- so served bytes cannot differ between planes.
/// Protocol-level problems (bad utf-8, bad JSON, unknown version)
/// answer typed frames and return `Continue`; a write failure
/// propagates as `Err` (the connection is broken).
///
/// Panic isolation: a handler bug must cost ONE connection, not the
/// process. The registry's own locks recover from poisoning (batcher,
/// stats rings), so serving state stays coherent for every other
/// connection; this connection closes with a typed `internal` frame
/// because mid-op output may be half-written.
pub(crate) fn process_frame(
    w: &mut dyn Write,
    registry: &Arc<TableRegistry>,
    stop: &AtomicBool,
    raw: &[u8],
) -> Result<FrameOut, WireError> {
    // the threaded plane hands over an already-validated String; the
    // event plane hands raw socket bytes -- validate here so the check
    // cannot be forgotten by a future caller
    let req = match std::str::from_utf8(raw) {
        Ok(r) => r,
        Err(e) => {
            // payload fully consumed -- the connection stays usable
            write_frame(w, &err_obj(
                "malformed", &format!("frame not utf-8: {e}"), vec![])
                .to_string())?;
            return Ok(FrameOut::Continue);
        }
    };
    let j = match Json::parse(req) {
        Ok(j) => j,
        Err(e) => {
            // answer typed and keep the connection: a JSON typo must
            // not silently drop an otherwise-healthy client
            write_frame(w, &err_obj(
                "malformed", &format!("bad request: {e}"), vec![])
                .to_string())?;
            return Ok(FrameOut::Continue);
        }
    };
    let version = match frame_version(&j) {
        Ok(v) => v,
        Err(e) => {
            // version negotiation: name the highest version we speak
            write_frame(w, &err_frame(&e).to_string())?;
            return Ok(FrameOut::Continue);
        }
    };
    let dispatched = catch_unwind(AssertUnwindSafe(|| {
        dispatch_op(&mut *w, registry, stop, &j, version)
    }));
    match dispatched {
        Ok(Ok(true)) => Ok(FrameOut::Continue),
        Ok(Ok(false)) => Ok(FrameOut::Shutdown),
        Ok(Err(e)) => Err(e),
        Err(payload) => {
            drop(payload);
            registry
                .conn_stats()
                .handler_panics
                .fetch_add(1, Ordering::Relaxed);
            let _ = write_frame(w, &err_obj(
                "internal",
                "handler panicked; closing this connection",
                vec![]).to_string());
            Ok(FrameOut::Closed)
        }
    }
}

/// Dispatch one parsed frame to its op handler. Returns `Ok(false)`
/// exactly when the op was `shutdown` (the connection closes after the
/// ack); every other handled frame is `Ok(true)`. Runs under the
/// caller's `catch_unwind` isolation barrier.
fn dispatch_op(
    stream: &mut dyn Write,
    registry: &Arc<TableRegistry>,
    stop: &AtomicBool,
    j: &Json,
    version: u64,
) -> Result<bool, WireError> {
    match j.get("op").and_then(|v| v.as_str()) {
        Some("lookup_bin") => {
            lookup_op(stream, registry, j, version, true)?
        }
        Some("lookup") => {
            lookup_op(stream, registry, j, version, false)?
        }
        Some("stats") => stats_op(stream, registry, j, version)?,
        Some(op @ ("tables" | "load" | "unload" | "demote" | "snapshot"
                   | "set_replicas" | "set_row_cache" | "lookup_fanout"
                   | "score" | "topk" | "fetch_artifact"))
            if version < 2 => {
            write_frame(stream, &err_obj(
                "needs_v2",
                &format!("op {op} requires protocol v2 (send \"v\": 2)"),
                vec![])
                .to_string())?
        }
        Some("lookup_fanout") => {
            fanout_op(stream, registry, j, version)?
        }
        Some("score") => score_op(stream, registry, j)?,
        Some("topk") => topk_op(stream, registry, j)?,
        Some("tables") => tables_op(stream, registry)?,
        Some("load") => load_op(stream, registry, j)?,
        Some("unload") => unload_op(stream, registry, j)?,
        Some("demote") => demote_op(stream, registry, j)?,
        Some("set_replicas") => {
            set_replicas_op(stream, registry, j)?
        }
        Some("set_row_cache") => {
            set_row_cache_op(stream, registry, j)?
        }
        Some("snapshot") => snapshot_op(stream, registry, j)?,
        Some("fetch_artifact") => fetch_artifact_op(stream, registry, j)?,
        Some("shutdown") => {
            stop.store(true, Ordering::Relaxed);
            write_frame(stream, &Json::obj(vec![
                ("ok", Json::Bool(true)),
            ]).to_string())?;
            return Ok(false);
        }
        // test-only panic injection for the isolation barrier; with
        // `debug_ops` off (the default, and the only thing the CLI or a
        // snapshot restore can produce) the guard fails and the name
        // falls through to `unknown_op` like any other stranger
        Some("debug_panic") if registry.config().debug_ops => {
            panic!("debug_panic: deliberate handler panic (test injection)")
        }
        other => {
            write_frame(stream, &err_obj(
                "unknown_op", &format!("unknown op {other:?}"), vec![])
                .to_string())?
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::sync::mpsc;

    use crate::backend::DenseTable;
    use crate::tensor::TensorF;

    fn toy_emb(n: usize, k: usize, dg: usize, s: usize) -> CompressedEmbedding {
        crate::dpq::toy_embedding(n, k, dg, s, 1)
    }

    fn spawn_server(server: Arc<EmbeddingServer>)
        -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        (rx.recv().unwrap(), h)
    }

    #[test]
    fn server_roundtrip_lookup_matches_local_reconstruct() {
        let emb = toy_emb(50, 8, 4, 3);
        let expect: Vec<Vec<f32>> =
            (0..5).map(|i| emb.reconstruct_row(i)).collect();
        let server = Arc::new(EmbeddingServer::single("emb", emb, 16));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        let rows = c.lookup("emb", &[0, 1, 2, 3, 4]).unwrap();
        assert_eq!((rows.n(), rows.d()), (5, 12));
        for (got, want) in rows.iter().zip(&expect) {
            for (a, b) in got.iter().zip(want) {
                assert!((a - b).abs() < 1e-4);
            }
        }
        let stats = c.stats(None).unwrap();
        assert!(stats.get("ids_served").unwrap().as_usize().unwrap() >= 5);
        // per-table latency shows up once a batch has been served
        let t = stats.get("tables").unwrap().get("emb").unwrap();
        assert!(t.get("batch_p50_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(t.get("batch_p99_s").unwrap().as_f64().unwrap() >= 0.0);
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn binary_lookup_matches_json_and_is_self_describing() {
        let emb = toy_emb(30, 8, 4, 2);
        let d = emb.d;
        let server = Arc::new(EmbeddingServer::single("emb", emb, 16));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        let ids = [3usize, 7, 3, 29];
        let a = c.lookup("emb", &ids).unwrap();
        // no d passed: the (n, d) header sizes the result
        let b = c.lookup_bin("emb", &ids).unwrap();
        assert_eq!((b.n(), b.d()), (ids.len(), d));
        assert_eq!(a, b, "json and binary must decode identically");
        // lookup_into with the right width
        let mut out = vec![0.0f32; ids.len() * d];
        assert_eq!(c.lookup_into("emb", &ids, &mut out).unwrap(), d);
        assert_eq!(out, b.as_slice());
        // ... and a wrong-width buffer is a typed error that leaves the
        // connection usable
        let mut bad = vec![0.0f32; ids.len() * (d - 1)];
        match c.lookup_into("emb", &ids, &mut bad) {
            Err(WireError::WidthMismatch { expected, got }) => {
                assert_eq!((expected, got), (d - 1, d));
            }
            other => panic!("expected WidthMismatch, got {other:?}"),
        }
        assert_eq!(c.lookup_bin("emb", &ids).unwrap(), b);
        // out-of-range id on binary: typed rejection, not a bare sentinel
        match c.lookup_bin("emb", &[999]) {
            Err(WireError::Rejected { code, .. }) => assert_eq!(code, "bad_ids"),
            other => panic!("expected bad_ids rejection, got {other:?}"),
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn server_rejects_out_of_range_and_unknown_table() {
        let server = Arc::new(EmbeddingServer::single("emb", toy_emb(10, 4, 2, 2), 8));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        match c.lookup("emb", &[99]) {
            Err(WireError::Rejected { code, .. }) => assert_eq!(code, "bad_ids"),
            other => panic!("{other:?}"),
        }
        match c.lookup("nope", &[1]) {
            Err(WireError::NoSuchTable(t)) => assert_eq!(t, "nope"),
            other => panic!("{other:?}"),
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// Regression: JSON and binary lookups must BOTH reject out-of-range
    /// ids (never clamp), and the connection must keep serving in-range
    /// requests afterwards. Also exercises empty id lists and malformed
    /// ids on raw v1 frames.
    #[test]
    fn out_of_range_rejected_on_both_protocols() {
        let emb = toy_emb(10, 4, 2, 2);
        let d = emb.d;
        let boundary = emb.reconstruct_row(9);
        let server = Arc::new(EmbeddingServer::single("emb", emb, 8));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        // vocab is 10: id 10 is the first invalid id on both protocols
        assert!(c.lookup("emb", &[3, 10]).is_err());
        assert!(c.lookup_bin("emb", &[3, 10]).is_err());
        // a clamping server would serve id 10 as row 9; a rejecting one
        // still serves the real row 9 afterwards
        let got = c.lookup_bin("emb", &[9]).unwrap();
        assert_eq!(got.row(0), &boundary[..]);
        // empty id lists are valid on both protocols (the binary
        // rejection sentinel is u32::MAX, NOT a short frame)
        assert_eq!(c.lookup("emb", &[]).unwrap().n(), 0);
        let empty = c.lookup_bin("emb", &[]).unwrap();
        assert_eq!((empty.n(), empty.d()), (0, d));
        // malformed ids (negative, fractional) are rejected too -- a
        // saturating/dropping parse would serve id 0 or a short response
        let mut raw = TcpStream::connect(addr).unwrap();
        for bad in [r#"{"op":"lookup","ids":[1,-2]}"#,
                    r#"{"op":"lookup","ids":[1.5]}"#] {
            write_frame(&mut raw, bad).unwrap();
            let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
            assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false),
                       "{bad} must be rejected");
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// v1 compatibility: version-less frames resolve to the default
    /// table, and a v1 `lookup_bin` response keeps the legacy headerless
    /// layout (bare `u32::MAX` sentinel on rejection).
    #[test]
    fn v1_frames_hit_default_table_with_legacy_binary_framing() {
        let emb = toy_emb(20, 8, 4, 2);
        let d = emb.d;
        let expect = emb.reconstruct_row(7);
        let registry = TableRegistry::new(ServerConfig::default());
        registry.insert("main", Arc::new(emb)).unwrap();
        registry
            .insert("other", Arc::new(DenseTable::new(
                TensorF::zeros(vec![4, 2])).unwrap()))
            .unwrap();
        let server = Arc::new(EmbeddingServer::new(registry));
        let (addr, h) = spawn_server(server.clone());
        let mut raw = TcpStream::connect(addr).unwrap();
        // v1 JSON lookup: no "v", no "table" -> default table "main"
        write_frame(&mut raw, r#"{"op":"lookup","ids":[7]}"#).unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
        let row: Vec<f32> = resp.get("vectors").unwrap().as_arr().unwrap()[0]
            .as_arr().unwrap().iter()
            .map(|x| x.as_f64().unwrap() as f32).collect();
        assert_eq!(row, expect);
        // v1 binary lookup: legacy headerless payload of n*d f32
        write_frame(&mut raw, r#"{"op":"lookup_bin","ids":[7,7]}"#).unwrap();
        let mut len4 = [0u8; 4];
        raw.read_exact(&mut len4).unwrap();
        let len = u32::from_le_bytes(len4) as usize;
        assert_eq!(len, 2 * d * 4, "v1 binary frame must have no header");
        let mut buf = vec![0u8; len];
        raw.read_exact(&mut buf).unwrap();
        let vals: Vec<f32> = buf.chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect();
        assert_eq!(&vals[..d], &expect[..]);
        // v1 binary rejection: bare sentinel, no trailing error frame
        write_frame(&mut raw, r#"{"op":"lookup_bin","ids":[999]}"#).unwrap();
        raw.read_exact(&mut len4).unwrap();
        assert_eq!(u32::from_le_bytes(len4), u32::MAX);
        // the connection is still alive and still v1-routable
        write_frame(&mut raw, r#"{"op":"stats"}"#).unwrap();
        let stats = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert!(stats.get("ids_served").unwrap().as_usize().unwrap() >= 3);
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn version_negotiation_rejects_unknown_versions() {
        let server = Arc::new(EmbeddingServer::single("emb", toy_emb(10, 4, 2, 2), 8));
        let (addr, h) = spawn_server(server.clone());
        let mut raw = TcpStream::connect(addr).unwrap();
        write_frame(&mut raw, r#"{"v":3,"op":"lookup","ids":[0]}"#).unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(resp.get("code").and_then(|v| v.as_str()),
                   Some("unsupported_version"));
        assert_eq!(resp.get("max_v").and_then(|v| v.as_usize()), Some(2));
        // v2 admin ops are refused on v1 frames, typed
        write_frame(&mut raw, r#"{"op":"tables"}"#).unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("code").and_then(|v| v.as_str()), Some("needs_v2"));
        // garbage JSON answers typed and keeps the connection
        let garbage = "not json at all";
        raw.write_all(&(garbage.len() as u32).to_le_bytes()).unwrap();
        raw.write_all(garbage.as_bytes()).unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("code").and_then(|v| v.as_str()), Some("malformed"));
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// One fan-out frame must answer exactly what per-table lookups
    /// would, section for section -- and reject the WHOLE frame, typed,
    /// when any section is bad (all-or-nothing), leaving the connection
    /// healthy.
    #[test]
    fn fanout_matches_per_table_lookups_and_rejects_whole_frame() {
        let emb = toy_emb(40, 8, 4, 3); // d = 12
        let registry = TableRegistry::new(ServerConfig::default());
        registry.insert("emb", Arc::new(emb)).unwrap();
        registry
            .insert("dense", Arc::new(DenseTable::new(
                TensorF::zeros(vec![40, 6])).unwrap()))
            .unwrap();
        let server = Arc::new(EmbeddingServer::new(registry));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        let a = c.lookup_bin("emb", &[0, 5, 39]).unwrap();
        let b = c.lookup_bin("dense", &[1, 2]).unwrap();
        let sections = c.lookup_fanout(&[
            ("emb", &[0, 5, 39][..]),
            ("dense", &[1, 2][..]),
            ("emb", &[][..]), // empty section stays self-describing
        ]).unwrap();
        assert_eq!(sections.len(), 3);
        assert_eq!(sections[0], a, "section 0 must match lookup_bin");
        assert_eq!(sections[1], b, "section 1 must match lookup_bin");
        assert_eq!((sections[2].n(), sections[2].d()), (0, 12));
        // all-or-nothing: a bad id in ANY section rejects the frame
        match c.lookup_fanout(&[("emb", &[0][..]), ("dense", &[999][..])]) {
            Err(WireError::Rejected { code, .. }) => assert_eq!(code, "bad_ids"),
            other => panic!("{other:?}"),
        }
        match c.lookup_fanout(&[("nope", &[0][..])]) {
            Err(WireError::NoSuchTable(t)) => assert_eq!(t, "nope"),
            other => panic!("{other:?}"),
        }
        // the connection survived both rejections
        assert_eq!(c.lookup_fanout(&[("emb", &[7][..])]).unwrap()[0],
                   c.lookup_bin("emb", &[7]).unwrap());
        // only complete fan-out frames are counted
        let st = c.stats(None).unwrap();
        assert_eq!(st.get("fanout_requests").unwrap().as_usize(), Some(2));
        // the op is v2-only: a v1 frame gets the typed needs_v2 code
        let mut raw = TcpStream::connect(addr).unwrap();
        write_frame(&mut raw, r#"{"op":"lookup_fanout","queries":[]}"#).unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("code").and_then(|v| v.as_str()), Some("needs_v2"));
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn hot_load_unload_over_the_wire() {
        let emb = toy_emb(24, 8, 4, 2);
        let row5 = emb.reconstruct_row(5);
        let path = std::env::temp_dir().join("dpq_server_hot_load.dpq");
        emb.save(&path).unwrap();
        let server = Arc::new(EmbeddingServer::single(
            "base", toy_emb(10, 4, 2, 2), 8));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        assert_eq!(c.tables().unwrap().len(), 1);
        let desc = c.admin_load("hot", path.to_str().unwrap()).unwrap();
        assert_eq!((desc.kind.as_str(), desc.vocab, desc.d), ("dpq", 24, 8));
        assert!(!desc.is_default, "first table stays default");
        let got = c.lookup_bin("hot", &[5]).unwrap();
        assert_eq!(got.row(0), &row5[..]);
        // duplicate load is typed
        match c.admin_load("hot", path.to_str().unwrap()) {
            Err(WireError::TableExists(t)) => assert_eq!(t, "hot"),
            other => panic!("{other:?}"),
        }
        let names: Vec<String> =
            c.tables().unwrap().into_iter().map(|t| t.name).collect();
        assert_eq!(names, vec!["base".to_string(), "hot".to_string()]);
        c.admin_unload("hot").unwrap();
        match c.lookup_bin("hot", &[5]) {
            Err(WireError::NoSuchTable(t)) => assert_eq!(t, "hot"),
            other => panic!("{other:?}"),
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// The compute-on-codes plane end to end: `score` over an explicit
    /// id list matches a client-side reconstruct-then-dot reference
    /// within the ADC tolerance, `topk` agrees with a full client-side
    /// sort (ids exact, best first, ties ascending), and every bad
    /// request is a typed rejection that leaves the connection healthy.
    #[test]
    fn score_and_topk_over_the_wire() {
        let emb = toy_emb(60, 8, 4, 3); // d = 12
        let d = emb.d;
        let rows: Vec<Vec<f32>> =
            (0..60).map(|i| emb.reconstruct_row(i)).collect();
        let query: Vec<f32> =
            (0..d).map(|j| ((j as f32) * 0.37).sin()).collect();
        let expect: Vec<f32> = rows
            .iter()
            .map(|r| crate::scoring::dot_serial(&query, r))
            .collect();
        let tol = crate::scoring::adc_tolerance(d);
        let registry = TableRegistry::new(ServerConfig::default());
        registry.insert("emb", Arc::new(emb)).unwrap();
        registry
            .insert("dense", Arc::new(DenseTable::new(
                TensorF::zeros(vec![10, 4])).unwrap()))
            .unwrap();
        let server = Arc::new(EmbeddingServer::new(registry));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        // score: explicit ids, duplicates allowed, id-list order
        let ids = [0usize, 7, 59, 7];
        let got = c.score("emb", &query, &ids).unwrap();
        for (g, &i) in got.iter().zip(&ids) {
            assert!((g - expect[i]).abs() <= tol,
                    "id {i}: lut {g} vs reference {}", expect[i]);
        }
        // topk matches a client-side full sort over the reference scores
        let mut order: Vec<usize> = (0..60).collect();
        order.sort_by(|&a, &b|
            expect[b].total_cmp(&expect[a]).then(a.cmp(&b)));
        let top = c.topk("emb", &query, 5, None).unwrap();
        assert_eq!(top.len(), 5);
        for (rank, (id, s)) in top.iter().enumerate() {
            assert_eq!(*id, order[rank], "rank {rank} id");
            assert!((s - expect[*id]).abs() <= tol);
        }
        // range restriction: ids stay inside the window; a window
        // smaller than k answers short, self-describing
        let windowed = c.topk("emb", &query, 60, Some((20, 30))).unwrap();
        assert_eq!(windowed.len(), 10);
        assert!(windowed.iter().all(|(id, _)| (20..30).contains(id)));
        // query_id: the query is row 3 of the same table
        let by_id = c.score_with_id("emb", 3, &[3, 5]).unwrap();
        for (g, &i) in by_id.iter().zip(&[3usize, 5]) {
            let want = crate::scoring::dot_serial(&rows[3], &rows[i]);
            assert!((g - want).abs() <= tol);
        }
        // dense tables take the exact path; an all-zero table scores 0
        // everywhere and ties break by ascending id
        let dz = c.topk("dense", &[1.0f32; 4], 3, None).unwrap();
        assert_eq!(dz, vec![(0, 0.0), (1, 0.0), (2, 0.0)]);
        // typed rejections -- each leaves the connection usable
        fn code_of<T: std::fmt::Debug>(r: Result<T, WireError>) -> String {
            match r {
                Err(WireError::Rejected { code, .. }) => code,
                other => panic!("expected typed rejection, got {other:?}"),
            }
        }
        assert_eq!(code_of(c.score("emb", &query[..d - 1], &[0])),
                   "width_mismatch");
        assert_eq!(code_of(c.score("emb", &query, &[60])), "bad_ids");
        assert_eq!(code_of(c.topk("emb", &query, 0, None)), "bad_k");
        assert_eq!(code_of(c.topk("emb", &query, 61, None)), "bad_k");
        assert_eq!(code_of(c.topk("emb", &query, 5, Some((40, 20)))),
                   "bad_range");
        assert_eq!(code_of(c.topk("emb", &query, 5, Some((0, 61)))),
                   "bad_range");
        match c.topk("nope", &query, 1, None) {
            Err(WireError::NoSuchTable(t)) => assert_eq!(t, "nope"),
            other => panic!("{other:?}"),
        }
        // non-finite query values are typed `malformed` at the protocol
        // layer (JSON `1e999` parses to +inf), and a v1 frame gets
        // needs_v2 -- raw frames, since Client can't emit either
        let mut raw = TcpStream::connect(addr).unwrap();
        write_frame(&mut raw,
            r#"{"v":2,"op":"score","table":"emb","ids":[0],"query":[1e999]}"#)
            .unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("code").and_then(|v| v.as_str()),
                   Some("malformed"));
        write_frame(&mut raw, r#"{"op":"topk","k":1,"query":[0]}"#).unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("code").and_then(|v| v.as_str()),
                   Some("needs_v2"));
        // missing query AND query_id is bad_request; so is missing k
        write_frame(&mut raw, r#"{"v":2,"op":"score","table":"emb","ids":[0]}"#)
            .unwrap();
        let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
        assert_eq!(resp.get("code").and_then(|v| v.as_str()),
                   Some("bad_request"));
        // counters + the score-latency ring surface in per-table stats
        let st = c.stats(Some("emb")).unwrap();
        assert!(st.get("score_requests").unwrap().as_usize().unwrap() >= 4);
        assert!(st.get("topk_requests").unwrap().as_usize().unwrap() >= 3);
        assert!(st.get("score_p50_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(st.get("score_p99_s").unwrap().as_f64().unwrap() >= 0.0);
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// A backend kind without the scoring capability (the trait
    /// default) answers `score`/`topk` with the typed
    /// `score_unsupported` code, never `internal`.
    #[test]
    fn score_without_capability_is_typed() {
        struct NoScore;
        impl crate::backend::EmbeddingBackend for NoScore {
            fn kind(&self) -> &'static str { "external" }
            fn d(&self) -> usize { 4 }
            fn vocab(&self) -> usize { 8 }
            fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
                out.fill(0.0);
                let _ = ids;
            }
            fn storage_bits(&self) -> usize { 8 * 4 * 32 }
        }
        let registry = TableRegistry::new(ServerConfig::default());
        registry.insert("ext", Arc::new(NoScore)).unwrap();
        let server = Arc::new(EmbeddingServer::new(registry));
        let (addr, h) = spawn_server(server.clone());
        let mut c = Client::connect(addr).unwrap();
        for r in [c.score("ext", &[0.0; 4], &[0]),
                  c.topk("ext", &[0.0; 4], 1, None).map(|_| vec![])] {
            match r {
                Err(WireError::Rejected { code, .. }) => {
                    assert_eq!(code, "score_unsupported")
                }
                other => panic!("{other:?}"),
            }
        }
        // lookups on the same table still work: the capability gap is
        // scoped to the scoring plane
        assert_eq!(c.lookup("ext", &[0, 7]).unwrap().n(), 2);
        c.shutdown().unwrap();
        h.join().unwrap();
    }
}
