//! Embedding-lookup server: serves compressed (DPQ) embeddings over TCP
//! with request micro-batching -- the L3 serving path demonstrating the
//! paper's inference claim (codebook lookup + concat is as cheap as a full
//! table lookup at a fraction of the memory).
//!
//! Wire protocol: length-prefixed JSON frames (u32 LE byte length + JSON).
//!   request:  {"op": "lookup", "ids": [1, 2, 3]}
//!             {"op": "lookup_bin", "ids": [...]}   (raw f32-LE response)
//!             {"op": "stats"}
//!             {"op": "shutdown"}
//!   response: {"ok": true, "vectors": [[...], ...]} | {"ok": true, ...}
//!   lookup_bin response: u32 LE frame length, then n*d f32 LE values
//!   (row-major). Binary lookups skip JSON float formatting entirely --
//!   see EXPERIMENTS.md §Perf for the measured speedup.
//!
//! Architecture: acceptor thread per connection pushes parsed requests to
//! a bounded channel; a single batcher thread drains up to `max_batch`
//! pending lookups, reconstructs rows in one pass over the codebook, and
//! completes each waiting request. std-only (no tokio in the offline
//! vendor set) -- the event loop is threads + channels.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::dpq::CompressedEmbedding;
use crate::jsonx::Json;

/// Server statistics (exposed via the `stats` op).
#[derive(Default)]
pub struct Stats {
    pub requests: AtomicU64,
    pub ids_served: AtomicU64,
    pub batches: AtomicU64,
}

/// A pending lookup: ids + completion slot.
struct Pending {
    ids: Vec<usize>,
    done: Arc<(Mutex<Option<Vec<Vec<f32>>>>, Condvar)>,
}

/// Micro-batching queue: lookups accumulate here; the batcher drains.
pub struct BatchQueue {
    q: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    pub max_batch: usize,
}

impl BatchQueue {
    pub fn new(max_batch: usize) -> Self {
        BatchQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new(), max_batch }
    }

    fn push(&self, p: Pending) {
        self.q.lock().unwrap().push_back(p);
        self.cv.notify_one();
    }

    /// Pop up to max_batch entries, waiting up to `timeout` for the first.
    fn pop_batch(&self, timeout: Duration) -> Vec<Pending> {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            let (qq, _) = self.cv.wait_timeout(q, timeout).unwrap();
            q = qq;
        }
        let take = q.len().min(self.max_batch);
        q.drain(..take).collect()
    }
}

/// The embedding server over a compressed DPQ table.
pub struct EmbeddingServer {
    pub emb: Arc<CompressedEmbedding>,
    pub stats: Arc<Stats>,
    queue: Arc<BatchQueue>,
    stop: Arc<AtomicBool>,
}

impl EmbeddingServer {
    pub fn new(emb: CompressedEmbedding, max_batch: usize) -> Self {
        EmbeddingServer {
            emb: Arc::new(emb),
            stats: Arc::new(Stats::default()),
            queue: Arc::new(BatchQueue::new(max_batch)),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Bind + serve until a `shutdown` op arrives. Returns the bound
    /// address via the callback before blocking (port 0 supported).
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        // batcher thread
        let batcher = {
            let emb = self.emb.clone();
            let queue = self.queue.clone();
            let stop = self.stop.clone();
            let stats = self.stats.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let batch = queue.pop_batch(Duration::from_millis(20));
                    if batch.is_empty() {
                        continue;
                    }
                    stats.batches.fetch_add(1, Ordering::Relaxed);
                    for p in batch {
                        let vecs: Vec<Vec<f32>> = p
                            .ids
                            .iter()
                            .map(|&i| emb.reconstruct_row(i.min(emb.vocab() - 1)))
                            .collect();
                        stats
                            .ids_served
                            .fetch_add(p.ids.len() as u64, Ordering::Relaxed);
                        let (slot, cv) = &*p.done;
                        *slot.lock().unwrap() = Some(vecs);
                        cv.notify_one();
                    }
                }
            })
        };
        // accept loop. Connection threads are detached: a thread exits when
        // its peer disconnects (or after serving `shutdown`). Joining them
        // here would deadlock shutdown against idle-but-open clients.
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let queue = self.queue.clone();
                    let stats = self.stats.clone();
                    let stop = self.stop.clone();
                    let vocab = self.emb.vocab();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, queue, stats, stop, vocab);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        let _ = batcher.join();
        Ok(())
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn handle_conn(
    mut stream: TcpStream,
    queue: Arc<BatchQueue>,
    stats: Arc<Stats>,
    stop: Arc<AtomicBool>,
    vocab: usize,
) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        let req = match read_frame(&mut stream) {
            Ok(r) => r,
            Err(_) => return Ok(()), // peer closed
        };
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let j = Json::parse(&req).map_err(|e| anyhow!("bad request: {e}"))?;
        match j.get("op").and_then(|v| v.as_str()) {
            Some("lookup_bin") => {
                let ids: Vec<usize> = j
                    .get("ids")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("lookup_bin without ids"))?
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect();
                if ids.iter().any(|&i| i >= vocab) {
                    // signal error as a zero-length frame
                    stream.write_all(&0u32.to_le_bytes())?;
                    continue;
                }
                let done = Arc::new((Mutex::new(None), Condvar::new()));
                queue.push(Pending { ids, done: done.clone() });
                let (slot, cv) = &*done;
                let mut guard = slot.lock().unwrap();
                while guard.is_none() {
                    guard = cv.wait(guard).unwrap();
                }
                let vecs = guard.take().unwrap();
                drop(guard);
                let total: usize = vecs.iter().map(|v| v.len()).sum();
                let mut payload = Vec::with_capacity(total * 4);
                for row in &vecs {
                    for v in row {
                        payload.extend_from_slice(&v.to_le_bytes());
                    }
                }
                stream.write_all(&(payload.len() as u32).to_le_bytes())?;
                stream.write_all(&payload)?;
            }
            Some("lookup") => {
                let ids: Vec<usize> = j
                    .get("ids")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("lookup without ids"))?
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect();
                if ids.iter().any(|&i| i >= vocab) {
                    write_frame(&mut stream, &Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str("id out of range")),
                    ]).to_string())?;
                    continue;
                }
                let done = Arc::new((Mutex::new(None), Condvar::new()));
                queue.push(Pending { ids, done: done.clone() });
                let (slot, cv) = &*done;
                let mut guard = slot.lock().unwrap();
                while guard.is_none() {
                    guard = cv.wait(guard).unwrap();
                }
                let vecs = guard.take().unwrap();
                let arr = Json::arr(
                    vecs.into_iter()
                        .map(|v| Json::arr(
                            v.into_iter().map(|x| Json::num(x as f64)).collect()))
                        .collect(),
                );
                write_frame(&mut stream, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("vectors", arr),
                ]).to_string())?;
            }
            Some("stats") => {
                write_frame(&mut stream, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("requests", Json::num(stats.requests.load(Ordering::Relaxed) as f64)),
                    ("ids_served", Json::num(stats.ids_served.load(Ordering::Relaxed) as f64)),
                    ("batches", Json::num(stats.batches.load(Ordering::Relaxed) as f64)),
                ]).to_string())?;
            }
            Some("shutdown") => {
                stop.store(true, Ordering::Relaxed);
                write_frame(&mut stream, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                ]).to_string())?;
                return Ok(());
            }
            other => bail!("unknown op {other:?}"),
        }
    }
}

// ---- framing helpers (also used by the client below) ----

pub fn read_frame(stream: &mut TcpStream) -> Result<String> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 64 << 20 {
        bail!("frame too large: {n}");
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

pub fn write_frame(stream: &mut TcpStream, payload: &str) -> Result<()> {
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload.as_bytes())?;
    Ok(())
}

/// Minimal blocking client for tests, benches and examples.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    pub fn lookup(&mut self, ids: &[usize]) -> Result<Vec<Vec<f32>>> {
        let req = Json::obj(vec![
            ("op", Json::str("lookup")),
            ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ]);
        write_frame(&mut self.stream, &req.to_string())?;
        let resp = Json::parse(&read_frame(&mut self.stream)?)
            .map_err(|e| anyhow!("bad response: {e}"))?;
        if resp.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            bail!("server error: {:?}", resp.get("error"));
        }
        Ok(resp
            .get("vectors")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing vectors"))?
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_f64().map(|f| f as f32))
                    .collect()
            })
            .collect())
    }

    /// Binary lookup: same semantics as `lookup`, raw f32-LE response.
    /// `d` is the embedding width (rows are returned flattened).
    pub fn lookup_bin(&mut self, ids: &[usize], d: usize) -> Result<Vec<Vec<f32>>> {
        let req = Json::obj(vec![
            ("op", Json::str("lookup_bin")),
            ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ]);
        write_frame(&mut self.stream, &req.to_string())?;
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        if n == 0 {
            bail!("server rejected lookup_bin (id out of range?)");
        }
        let mut buf = vec![0u8; n];
        self.stream.read_exact(&mut buf)?;
        if n != ids.len() * d * 4 {
            bail!("unexpected payload size {n}");
        }
        Ok(buf
            .chunks_exact(d * 4)
            .map(|row| {
                row.chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect()
            })
            .collect())
    }

    pub fn stats(&mut self) -> Result<Json> {
        write_frame(&mut self.stream, &Json::obj(vec![
            ("op", Json::str("stats")),
        ]).to_string())?;
        Json::parse(&read_frame(&mut self.stream)?)
            .map_err(|e| anyhow!("bad response: {e}"))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        write_frame(&mut self.stream, &Json::obj(vec![
            ("op", Json::str("shutdown")),
        ]).to_string())?;
        let _ = read_frame(&mut self.stream);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    use crate::dpq::Codebook;
    use crate::tensor::{TensorF, TensorI};
    use crate::util::Rng;

    fn toy_emb(n: usize, k: usize, dg: usize, s: usize) -> CompressedEmbedding {
        let mut rng = Rng::new(1);
        let codes = TensorI::new(vec![n, dg],
                                 (0..n * dg).map(|_| rng.below(k) as i32).collect())
            .unwrap();
        let values = TensorF::new(vec![k, dg, s],
                                  (0..k * dg * s).map(|_| rng.normal()).collect())
            .unwrap();
        CompressedEmbedding::new(Codebook::from_codes(&codes, k).unwrap(),
                                 values, false).unwrap()
    }

    #[test]
    fn batch_queue_drains_up_to_max() {
        let q = BatchQueue::new(3);
        for _ in 0..5 {
            q.push(Pending {
                ids: vec![0],
                done: Arc::new((Mutex::new(None), Condvar::new())),
            });
        }
        let b1 = q.pop_batch(Duration::from_millis(1));
        assert_eq!(b1.len(), 3);
        let b2 = q.pop_batch(Duration::from_millis(1));
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn server_roundtrip_lookup_matches_local_reconstruct() {
        let emb = toy_emb(50, 8, 4, 3);
        let expect: Vec<Vec<f32>> =
            (0..5).map(|i| emb.reconstruct_row(i)).collect();
        let server = Arc::new(EmbeddingServer::new(emb, 16));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                .unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        let vecs = c.lookup(&[0, 1, 2, 3, 4]).unwrap();
        for (got, want) in vecs.iter().zip(&expect) {
            for (a, b) in got.iter().zip(want) {
                assert!((a - b).abs() < 1e-4);
            }
        }
        let stats = c.stats().unwrap();
        assert!(stats.get("ids_served").unwrap().as_usize().unwrap() >= 5);
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn binary_lookup_matches_json_lookup() {
        let emb = toy_emb(30, 8, 4, 2);
        let d = emb.d;
        let server = Arc::new(EmbeddingServer::new(emb, 16));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        let ids = [3usize, 7, 3, 29];
        let a = c.lookup(&ids).unwrap();
        let b = c.lookup_bin(&ids, d).unwrap();
        for (x, y) in a.iter().zip(&b) {
            for (p, q) in x.iter().zip(y) {
                assert!((p - q).abs() < 1e-4);
            }
        }
        assert!(c.lookup_bin(&[999], d).is_err());
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn server_rejects_out_of_range() {
        let server = Arc::new(EmbeddingServer::new(toy_emb(10, 4, 2, 2), 8));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        assert!(c.lookup(&[99]).is_err());
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn timing_instant_smoke() {
        // keep Instant import exercised even if other tests change
        let t = Instant::now();
        assert!(t.elapsed() < Duration::from_secs(5));
    }
}
