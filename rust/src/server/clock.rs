//! Injectable time source for the registry's TTL-idle tracking.
//!
//! TTL eviction ("demote a table nobody has looked up for `--ttl`
//! seconds") is untestable against the real clock: a test would have to
//! sleep through the TTL, and "demoted exactly at the deadline" could
//! never be asserted at all. The registry therefore reads time through
//! the [`Clock`] trait. Production uses [`MonotonicClock`] (a plain
//! monotonic `Instant`); tests inject a [`ManualClock`] and advance it
//! by hand, which makes every TTL decision -- fire at exactly the
//! deadline, survive one tick before it, compose with the memory
//! budget -- a deterministic assertion instead of a sleep-and-hope.
//!
//! The clock only feeds *idle-time* decisions. LRU ordering keeps using
//! the registry's logical tick counter (resolution-ordered, no time at
//! all), and latency rings keep using `Instant` directly -- measured
//! wall time is a report, not a decision, so it does not need to be
//! injectable.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A monotonic time source: `now()` returns the time elapsed since an
/// arbitrary fixed origin (the clock's creation for the production
/// implementation). Implementations must never go backwards.
pub trait Clock: Send + Sync {
    /// Monotonic time since the clock's origin.
    fn now(&self) -> Duration;
}

/// The production [`Clock`]: monotonic wall time since the clock was
/// created, via [`Instant`]. Immune to system-clock steps (NTP, DST).
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    /// A monotonic clock whose origin is "now".
    pub fn new() -> Self {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> Duration {
        self.origin.elapsed()
    }
}

/// A deterministic [`Clock`] for tests: time starts at zero and moves
/// only when [`advance`](Self::advance) / [`set`](Self::set) are
/// called. Injecting one into a registry makes TTL eviction a pure
/// function of the test's explicit ticks.
#[derive(Default)]
pub struct ManualClock {
    now: Mutex<Duration>,
}

impl ManualClock {
    /// A manual clock frozen at `t = 0`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Move time forward by `d`.
    pub fn advance(&self, d: Duration) {
        let mut now = self.now.lock().unwrap();
        *now = now.saturating_add(d);
    }

    /// Jump to an absolute time since the origin. Clamped to never go
    /// backwards (a [`Clock`] is monotonic by contract).
    pub fn set(&self, t: Duration) {
        let mut now = self.now.lock().unwrap();
        if t > *now {
            *now = t;
        }
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Duration {
        *self.now.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_advances() {
        let c = MonotonicClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_moves_only_on_ticks_and_never_backwards() {
        let c = ManualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_secs(5));
        assert_eq!(c.now(), Duration::from_secs(5));
        c.set(Duration::from_secs(3)); // backwards: clamped
        assert_eq!(c.now(), Duration::from_secs(5));
        c.set(Duration::from_secs(9));
        assert_eq!(c.now(), Duration::from_secs(9));
        c.advance(Duration::from_millis(500));
        assert_eq!(c.now(), Duration::from_millis(9500));
    }
}
