//! Wire protocol: versioned, table-routed frames plus the typed [`Client`].
//!
//! Every request is a length-prefixed JSON frame (u32 LE byte length +
//! JSON object). A request carries its protocol version in `"v"`; a frame
//! with no `"v"` field is protocol **v1** (the original single-table
//! protocol) and is routed to the server's default table. See the
//! [`server`](crate::server) module docs for the full op catalogue and
//! framing of each response.
//!
//! Binary lookup responses are **self-describing** under v2: the frame
//! payload starts with a `(n, d)` u32 LE header, so no client ever has to
//! guess the embedding width (the v1 `lookup_bin(ids, d)` API wart). A
//! v1 `lookup_bin` request still receives the legacy headerless payload.
//!
//! Errors are typed end to end: server rejections carry a machine
//! `"code"` alongside the human `"error"` string, and the client maps
//! them onto [`WireError`] variants (a width mismatch surfaces as
//! [`WireError::WidthMismatch`], never a payload-size guess).

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::jsonx::Json;

/// Highest protocol version this build speaks.
pub const VERSION: u64 = 2;

/// Hard cap on any single frame (requests and JSON responses).
pub(crate) const MAX_FRAME: usize = 64 << 20;

/// Typed wire/protocol error. Implements `std::error::Error`, so it
/// converts into `anyhow::Error` at call sites that don't match on it.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// Socket-level failure (connect, read, write, peer hangup).
    Io(String),
    /// A frame that violates the protocol (bad JSON, ragged rows, short
    /// binary header, oversized frame).
    Malformed(String),
    /// The server does not speak the requested protocol version.
    UnsupportedVersion { max: u64 },
    /// The named table (or the default, when none was named) is not
    /// loaded on the server.
    NoSuchTable(String),
    /// `load` would overwrite an already-registered table.
    TableExists(String),
    /// The caller's buffer implies a different embedding width than the
    /// `(n, d)` header the server sent.
    WidthMismatch { expected: usize, got: usize },
    /// Any other server-side rejection; `code` is the machine-readable
    /// discriminator from the wire (e.g. `"bad_ids"`, `"load_failed"`).
    Rejected { code: String, message: String },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(m) => write!(f, "io error: {m}"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
            WireError::UnsupportedVersion { max } => {
                write!(f, "unsupported protocol version (server max v{max})")
            }
            WireError::NoSuchTable(t) => write!(f, "no such table {t:?}"),
            WireError::TableExists(t) => write!(f, "table {t:?} already loaded"),
            WireError::WidthMismatch { expected, got } => write!(
                f,
                "embedding width mismatch: caller buffer implies d={expected}, \
                 server table has d={got}"
            ),
            WireError::Rejected { code, message } => {
                write!(f, "server rejected request [{code}]: {message}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e.to_string())
    }
}

impl WireError {
    /// Machine code used on the wire for this error.
    pub(crate) fn code(&self) -> &str {
        match self {
            WireError::Io(_) => "io",
            WireError::Malformed(_) => "malformed",
            WireError::UnsupportedVersion { .. } => "unsupported_version",
            WireError::NoSuchTable(_) => "no_such_table",
            WireError::TableExists(_) => "table_exists",
            WireError::WidthMismatch { .. } => "width_mismatch",
            WireError::Rejected { code, .. } => code,
        }
    }

    /// Reconstruct a typed error from a server `{"ok": false, ...}` frame.
    pub fn from_response(j: &Json) -> WireError {
        let msg = j
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap_or("unknown server error")
            .to_string();
        let named = |key: &str| {
            j.get(key).and_then(|v| v.as_str()).unwrap_or("?").to_string()
        };
        match j.get("code").and_then(|v| v.as_str()) {
            Some("no_such_table") => WireError::NoSuchTable(named("table")),
            Some("table_exists") => WireError::TableExists(named("table")),
            Some("unsupported_version") => WireError::UnsupportedVersion {
                max: j.get("max_v").and_then(|v| v.as_usize()).unwrap_or(1) as u64,
            },
            Some(code) => WireError::Rejected { code: code.into(), message: msg },
            None => WireError::Rejected { code: "error".into(), message: msg },
        }
    }
}

/// Build a `{"ok": false}` response carrying a machine code; `extra`
/// appends error-specific fields (e.g. the offending table name).
pub(crate) fn err_obj(code: &str, msg: &str, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("code", Json::str(code)),
        ("error", Json::str(msg)),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

/// The error frame for a typed [`WireError`], with its extra fields.
pub(crate) fn err_frame(e: &WireError) -> Json {
    let extra = match e {
        WireError::UnsupportedVersion { max } => {
            vec![("max_v", Json::num(*max as f64))]
        }
        WireError::NoSuchTable(t) | WireError::TableExists(t) => {
            vec![("table", Json::str(t.as_str()))]
        }
        _ => Vec::new(),
    };
    err_obj(e.code(), &e.to_string(), extra)
}

/// Resolve a request frame's protocol version: no `"v"` field means v1.
pub(crate) fn frame_version(j: &Json) -> Result<u64, WireError> {
    match j.get("v") {
        None => Ok(1),
        Some(v) => match v.as_f64() {
            Some(x) if x == 1.0 => Ok(1),
            Some(x) if x == 2.0 => Ok(2),
            _ => Err(WireError::UnsupportedVersion { max: VERSION }),
        },
    }
}

/// Strictly parse the request's `ids` array: every element must be a
/// non-negative integer JSON number. Anything else (negative, fractional,
/// string, null) returns `Ok(None)` so the caller can reject -- never
/// drop or saturate-clamp a malformed id (`-1 as usize` would silently
/// become id 0). A missing or non-array `ids` is an error.
pub(crate) fn parse_ids(j: &Json, op: &str) -> Result<Option<Vec<usize>>, WireError> {
    let arr = j
        .get("ids")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| WireError::Malformed(format!("{op} without ids")))?;
    Ok(arr
        .iter()
        .map(|x| match x.as_f64() {
            Some(n) if n >= 0.0
                && n.fract() == 0.0
                && n <= usize::MAX as f64 => Some(n as usize),
            _ => None,
        })
        .collect())
}

// ---- framing helpers (shared by server and client) ----

pub fn read_frame(stream: &mut TcpStream) -> Result<String, WireError> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(WireError::Malformed(format!("frame too large: {n}")));
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    String::from_utf8(buf)
        .map_err(|e| WireError::Malformed(format!("frame not utf-8: {e}")))
}

pub fn write_frame(stream: &mut TcpStream, payload: &str) -> Result<(), WireError> {
    if payload.len() as u64 >= u32::MAX as u64 {
        // fail loudly instead of wrapping the u32 length prefix
        return Err(WireError::Malformed(format!(
            "frame too large: {} bytes", payload.len())));
    }
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload.as_bytes())?;
    Ok(())
}

/// Server side: encode a binary lookup response. v2 frames are
/// self-describing (`u32 n | u32 d` header before the f32 rows); v1
/// frames keep the legacy headerless payload.
pub(crate) fn write_bin_rows(
    stream: &mut TcpStream,
    version: u64,
    n: usize,
    d: usize,
    flat: &[f32],
) -> Result<(), WireError> {
    debug_assert_eq!(flat.len(), n * d);
    let header = if version >= 2 { 8u64 } else { 0 };
    let bytes = header + flat.len() as u64 * 4;
    // Enforce the SAME bound the client's read side enforces (MAX_FRAME,
    // not just the u32 prefix limit): a response the peer refuses to
    // read would leave megabytes unread on the socket and desync every
    // later frame on the connection.
    if bytes > MAX_FRAME as u64 || n as u64 > u32::MAX as u64 || d as u64 > u32::MAX as u64 {
        return Err(WireError::Malformed(format!(
            "lookup_bin response of {bytes} bytes exceeds the frame cap \
             ({MAX_FRAME})")));
    }
    let mut payload = Vec::with_capacity(bytes as usize);
    if version >= 2 {
        payload.extend_from_slice(&(n as u32).to_le_bytes());
        payload.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for v in flat {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(&payload)?;
    Ok(())
}

/// Server side: reject a binary lookup. The `u32::MAX` sentinel can never
/// be a real frame length (an empty id list legitimately answers with a
/// zero-length v1 payload / 8-byte v2 header). Under v2 the sentinel is
/// followed by a JSON error frame so the rejection is self-describing;
/// v1 keeps the bare sentinel.
pub(crate) fn write_bin_reject(
    stream: &mut TcpStream,
    version: u64,
    e: &WireError,
) -> Result<(), WireError> {
    stream.write_all(&u32::MAX.to_le_bytes())?;
    if version >= 2 {
        write_frame(stream, &err_frame(e).to_string())?;
    }
    Ok(())
}

/// A lookup result: `n` rows of width `d`, flat row-major storage.
#[derive(Debug, Clone, PartialEq)]
pub struct Rows {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

impl Rows {
    pub(crate) fn new(n: usize, d: usize, data: Vec<f32>) -> Rows {
        debug_assert_eq!(data.len(), n * d);
        Rows { n, d, data }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.d.max(1))
    }

    pub fn into_vecs(self) -> Vec<Vec<f32>> {
        let d = self.d.max(1);
        self.data.chunks_exact(d).map(|r| r.to_vec()).collect()
    }
}

/// One served table as reported by the `tables` op.
#[derive(Debug, Clone, PartialEq)]
pub struct TableDesc {
    pub name: String,
    pub kind: String,
    pub vocab: usize,
    pub d: usize,
    pub storage_bits: usize,
    pub compression_ratio: f64,
    pub shards: usize,
    pub is_default: bool,
}

impl TableDesc {
    pub(crate) fn from_json(j: &Json, default_name: Option<&str>) -> Result<TableDesc, WireError> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| WireError::Malformed("table desc without name".into()))?
            .to_string();
        let get = |k: &str| j.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        Ok(TableDesc {
            is_default: default_name == Some(name.as_str()),
            kind: j.get("kind").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            vocab: get("vocab"),
            d: get("d"),
            storage_bits: get("storage_bits"),
            compression_ratio: j
                .get("compression_ratio")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            shards: get("shards").max(1),
            name,
        })
    }
}

/// Blocking protocol-v2 client used by tests, benches, examples and the
/// CLI. Every lookup names its table; `tables()` and the `admin_*` ops
/// manage the server's registry hot.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self, WireError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// Send one JSON request frame and parse the JSON response; a
    /// `{"ok": false}` response becomes a typed [`WireError`].
    fn request(&mut self, req: Json) -> Result<Json, WireError> {
        write_frame(&mut self.stream, &req.to_string())?;
        let j = Json::parse(&read_frame(&mut self.stream)?)
            .map_err(WireError::Malformed)?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(j)
        } else {
            Err(WireError::from_response(&j))
        }
    }

    fn lookup_req(op: &str, table: &str, ids: &[usize]) -> Json {
        Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str(op)),
            ("table", Json::str(table)),
            ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ])
    }

    /// JSON lookup against a named table.
    pub fn lookup(&mut self, table: &str, ids: &[usize]) -> Result<Rows, WireError> {
        let j = self.request(Self::lookup_req("lookup", table, ids))?;
        let vecs = j
            .get("vectors")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| WireError::Malformed("response without vectors".into()))?;
        let n = vecs.len();
        let d = j
            .get("d")
            .and_then(|v| v.as_usize())
            .or_else(|| vecs.first().and_then(|r| r.as_arr()).map(|r| r.len()))
            .unwrap_or(0);
        let mut data = Vec::with_capacity(n * d);
        for row in vecs {
            let row = row
                .as_arr()
                .ok_or_else(|| WireError::Malformed("vectors row not an array".into()))?;
            if row.len() != d {
                return Err(WireError::Malformed(format!(
                    "ragged response: row of {} values, d={d}", row.len())));
            }
            for x in row {
                data.push(x.as_f64().ok_or_else(|| {
                    WireError::Malformed("non-numeric vector entry".into())
                })? as f32);
            }
        }
        Ok(Rows::new(n, d, data))
    }

    /// Binary lookup: same semantics as [`lookup`](Self::lookup), raw
    /// f32-LE rows. The response's `(n, d)` header sizes the result -- the
    /// caller never passes (or guesses) the embedding width.
    pub fn lookup_bin(&mut self, table: &str, ids: &[usize]) -> Result<Rows, WireError> {
        write_frame(&mut self.stream,
                    &Self::lookup_req("lookup_bin", table, ids).to_string())?;
        self.read_bin_response()
    }

    /// Binary lookup straight into a caller buffer of `ids.len() * d`
    /// floats. Returns the table's `d`. If the buffer implies a different
    /// width than the response header, the error is a typed
    /// [`WireError::WidthMismatch`] -- and the payload is still drained,
    /// so the connection stays usable.
    pub fn lookup_into(
        &mut self,
        table: &str,
        ids: &[usize],
        out: &mut [f32],
    ) -> Result<usize, WireError> {
        write_frame(&mut self.stream,
                    &Self::lookup_req("lookup_bin", table, ids).to_string())?;
        let rows = self.read_bin_response()?;
        if rows.n() != ids.len() {
            return Err(WireError::Malformed(format!(
                "server answered {} rows for {} ids", rows.n(), ids.len())));
        }
        if out.len() != rows.n() * rows.d() {
            let expected =
                if ids.is_empty() { 0 } else { out.len() / ids.len() };
            return Err(WireError::WidthMismatch { expected, got: rows.d() });
        }
        out.copy_from_slice(rows.as_slice());
        Ok(rows.d())
    }

    fn read_bin_response(&mut self) -> Result<Rows, WireError> {
        let mut len4 = [0u8; 4];
        self.stream.read_exact(&mut len4)?;
        let len32 = u32::from_le_bytes(len4);
        if len32 == u32::MAX {
            // v2 rejection sentinel: a JSON error frame follows
            let j = Json::parse(&read_frame(&mut self.stream)?)
                .map_err(WireError::Malformed)?;
            return Err(WireError::from_response(&j));
        }
        let len = len32 as usize;
        if len > MAX_FRAME {
            return Err(WireError::Malformed(format!("frame too large: {len}")));
        }
        if len < 8 {
            return Err(WireError::Malformed(format!(
                "binary frame of {len} bytes is shorter than the (n, d) header")));
        }
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        let n = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        let d = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        if len != 8 + n * d * 4 {
            return Err(WireError::Malformed(format!(
                "binary frame of {len} bytes does not match header n={n} d={d}")));
        }
        let data = buf[8..]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Rows::new(n, d, data))
    }

    /// List the served tables (name, kind, shape, storage, default flag).
    pub fn tables(&mut self) -> Result<Vec<TableDesc>, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("tables")),
        ]))?;
        let default = j.get("default").and_then(|v| v.as_str()).map(str::to_string);
        j.get("tables")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| WireError::Malformed("response without tables".into()))?
            .iter()
            .map(|t| TableDesc::from_json(t, default.as_deref()))
            .collect()
    }

    /// Per-table serving stats; `table` narrows to one table's flat
    /// object, `None` returns the aggregate plus a per-table map.
    pub fn stats(&mut self, table: Option<&str>) -> Result<Json, WireError> {
        let mut pairs = vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("stats")),
        ];
        if let Some(t) = table {
            pairs.push(("table", Json::str(t)));
        }
        self.request(Json::obj(pairs))
    }

    /// Hot-load a `.dpq` artifact from a server-side path as a new table.
    pub fn admin_load(&mut self, table: &str, path: &str) -> Result<TableDesc, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("load")),
            ("table", Json::str(table)),
            ("path", Json::str(path)),
        ]))?;
        let desc = j
            .get("table")
            .ok_or_else(|| WireError::Malformed("load response without table".into()))?;
        TableDesc::from_json(desc, j.get("default").and_then(|v| v.as_str()))
    }

    /// Hot-unload a table; its in-flight lookups fail typed, later
    /// lookups get [`WireError::NoSuchTable`].
    pub fn admin_unload(&mut self, table: &str) -> Result<(), WireError> {
        self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("unload")),
            ("table", Json::str(table)),
        ]))?;
        Ok(())
    }

    pub fn shutdown(&mut self) -> Result<(), WireError> {
        write_frame(&mut self.stream, &Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("shutdown")),
        ]).to_string())?;
        let _ = read_frame(&mut self.stream);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_version_resolution() {
        let v1 = Json::parse(r#"{"op":"lookup","ids":[]}"#).unwrap();
        assert_eq!(frame_version(&v1).unwrap(), 1);
        let v1x = Json::parse(r#"{"v":1,"op":"lookup"}"#).unwrap();
        assert_eq!(frame_version(&v1x).unwrap(), 1);
        let v2 = Json::parse(r#"{"v":2,"op":"lookup"}"#).unwrap();
        assert_eq!(frame_version(&v2).unwrap(), 2);
        for bad in [r#"{"v":3}"#, r#"{"v":0}"#, r#"{"v":1.5}"#, r#"{"v":"2"}"#] {
            let j = Json::parse(bad).unwrap();
            assert_eq!(
                frame_version(&j).unwrap_err(),
                WireError::UnsupportedVersion { max: VERSION },
                "{bad}"
            );
        }
    }

    #[test]
    fn parse_ids_strict() {
        let ok = Json::parse(r#"{"ids":[0,3,12]}"#).unwrap();
        assert_eq!(parse_ids(&ok, "lookup").unwrap(), Some(vec![0, 3, 12]));
        for bad in [r#"{"ids":[1,-2]}"#, r#"{"ids":[1.5]}"#, r#"{"ids":["3"]}"#,
                    r#"{"ids":[null]}"#] {
            let j = Json::parse(bad).unwrap();
            assert_eq!(parse_ids(&j, "lookup").unwrap(), None, "{bad}");
        }
        let missing = Json::parse(r#"{"op":"lookup"}"#).unwrap();
        assert!(parse_ids(&missing, "lookup").is_err());
    }

    #[test]
    fn wire_error_roundtrips_through_frames() {
        for e in [
            WireError::NoSuchTable("emb".into()),
            WireError::TableExists("emb".into()),
            WireError::UnsupportedVersion { max: VERSION },
            WireError::Rejected { code: "bad_ids".into(),
                                  message: "ids must be integers".into() },
        ] {
            let frame = err_frame(&e);
            assert_eq!(frame.get("ok").and_then(|v| v.as_bool()), Some(false));
            let back = WireError::from_response(&frame);
            match (&e, &back) {
                (WireError::Rejected { code: a, .. },
                 WireError::Rejected { code: b, .. }) => assert_eq!(a, b),
                _ => assert_eq!(e, back),
            }
        }
    }

    #[test]
    fn rows_accessors() {
        let r = Rows::new(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(r.n(), 3);
        assert_eq!(r.d(), 2);
        assert_eq!(r.row(1), &[3.0, 4.0]);
        assert_eq!(r.iter().count(), 3);
        assert_eq!(r.clone().into_vecs()[2], vec![5.0, 6.0]);
        let empty = Rows::new(0, 0, vec![]);
        assert!(empty.is_empty());
        assert_eq!(empty.into_vecs().len(), 0);
    }
}
