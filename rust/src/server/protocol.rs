//! Wire protocol: versioned, table-routed frames plus the typed [`Client`].
//!
//! Every request is a length-prefixed JSON frame (u32 LE byte length +
//! JSON object). A request carries its protocol version in `"v"`; a frame
//! with no `"v"` field is protocol **v1** (the original single-table
//! protocol) and is routed to the server's default table. See the
//! [`server`](crate::server) module docs for the full op catalogue and
//! framing of each response.
//!
//! Binary lookup responses are **self-describing** under v2: the frame
//! payload starts with a `(n, d)` u32 LE header, so no client ever has to
//! guess the embedding width (the v1 `lookup_bin(ids, d)` API wart). A
//! v1 `lookup_bin` request still receives the legacy headerless payload.
//!
//! Errors are typed end to end: server rejections carry a machine
//! `"code"` alongside the human `"error"` string, and the client maps
//! them onto [`WireError`] variants (a width mismatch surfaces as
//! [`WireError::WidthMismatch`], never a payload-size guess).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use crate::jsonx::Json;

/// Highest protocol version this build speaks.
pub const VERSION: u64 = 2;

/// Hard cap on any single frame (requests and JSON responses).
pub(crate) const MAX_FRAME: usize = 64 << 20;

/// Hard cap on the number of sections (queries) in one `lookup_fanout`
/// frame. Section count is otherwise bounded only by how many `{"ids":
/// []}` objects fit in a 64 MiB frame (~millions), and each section
/// costs a batcher round trip -- an amplification a hostile client could
/// use to stall a server with one cheap frame. 1024 tables per request
/// is far beyond any recommender fan-out.
pub(crate) const MAX_FANOUT_SECTIONS: usize = 1024;

/// Length-prefix sentinel announcing a v2 **streamed** response: data
/// chunks follow (each `u32 LE len` in `1..=STREAM_CHUNK` plus bytes)
/// until a zero length, then one length-prefixed JSON terminal frame
/// (`{"ok":true,"bytes":..,"chunks":..}` on success, a typed error
/// frame on a mid-stream abort). Distinct from the `u32::MAX` rejection
/// sentinel; like it, this value can never be a real frame length
/// (both exceed [`MAX_FRAME`]). Streaming is strictly opt-in via
/// `"stream": true` on the request, so v1/older clients never see it.
pub(crate) const STREAM_SENTINEL: u32 = u32::MAX - 1;

/// Hard cap on one streamed chunk. The assembled payload may exceed
/// [`MAX_FRAME`] (that is the point of streaming); each chunk stays
/// small so neither side ever needs an oversized contiguous read.
pub(crate) const STREAM_CHUNK: usize = 256 << 10;

/// Typed wire/protocol error. Implements `std::error::Error`, so it
/// converts into `anyhow::Error` at call sites that don't match on it.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// Socket-level failure (connect, read, write, peer hangup).
    Io(String),
    /// A read or write deadline set via [`Client::with_timeout`]
    /// expired before the peer answered. Distinct from [`Io`](Self::Io)
    /// so callers (e.g. `repro hydrate`) can tell a wedged-but-alive
    /// peer from a dead socket.
    Timeout(String),
    /// A frame that violates the protocol (bad JSON, ragged rows, short
    /// binary header, oversized frame).
    Malformed(String),
    /// The server does not speak the requested protocol version.
    UnsupportedVersion { max: u64 },
    /// The named table (or the default, when none was named) is not
    /// loaded on the server.
    NoSuchTable(String),
    /// `load` would overwrite an already-registered table.
    TableExists(String),
    /// The caller's buffer implies a different embedding width than the
    /// `(n, d)` header the server sent.
    WidthMismatch { expected: usize, got: usize },
    /// Any other server-side rejection; `code` is the machine-readable
    /// discriminator from the wire (e.g. `"bad_ids"`, `"load_failed"`).
    Rejected { code: String, message: String },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(m) => write!(f, "io error: {m}"),
            WireError::Timeout(m) => write!(f, "deadline expired: {m}"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
            WireError::UnsupportedVersion { max } => {
                write!(f, "unsupported protocol version (server max v{max})")
            }
            WireError::NoSuchTable(t) => write!(f, "no such table {t:?}"),
            WireError::TableExists(t) => write!(f, "table {t:?} already loaded"),
            WireError::WidthMismatch { expected, got } => write!(
                f,
                "embedding width mismatch: caller buffer implies d={expected}, \
                 server table has d={got}"
            ),
            WireError::Rejected { code, message } => {
                write!(f, "server rejected request [{code}]: {message}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            // Both kinds mean "the socket deadline fired": Unix reports
            // WouldBlock on an SO_RCVTIMEO expiry, Windows TimedOut.
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                WireError::Timeout(e.to_string())
            }
            _ => WireError::Io(e.to_string()),
        }
    }
}

impl WireError {
    /// Machine code used on the wire for this error.
    pub(crate) fn code(&self) -> &str {
        match self {
            WireError::Io(_) => "io",
            WireError::Timeout(_) => "timeout",
            WireError::Malformed(_) => "malformed",
            WireError::UnsupportedVersion { .. } => "unsupported_version",
            WireError::NoSuchTable(_) => "no_such_table",
            WireError::TableExists(_) => "table_exists",
            WireError::WidthMismatch { .. } => "width_mismatch",
            WireError::Rejected { code, .. } => code,
        }
    }

    /// Reconstruct a typed error from a server `{"ok": false, ...}` frame.
    pub fn from_response(j: &Json) -> WireError {
        let msg = j
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap_or("unknown server error")
            .to_string();
        let named = |key: &str| {
            j.get(key).and_then(|v| v.as_str()).unwrap_or("?").to_string()
        };
        match j.get("code").and_then(|v| v.as_str()) {
            Some("no_such_table") => WireError::NoSuchTable(named("table")),
            Some("table_exists") => WireError::TableExists(named("table")),
            Some("unsupported_version") => WireError::UnsupportedVersion {
                max: j.get("max_v").and_then(|v| v.as_usize()).unwrap_or(1) as u64,
            },
            Some(code) => WireError::Rejected { code: code.into(), message: msg },
            None => WireError::Rejected { code: "error".into(), message: msg },
        }
    }
}

/// The typed `too_large` rejection every response writer raises BEFORE
/// any bytes hit the socket. A payload over `u32::MAX` would silently
/// truncate the length prefix and desync the stream; one over
/// [`MAX_FRAME`] would be refused by the peer's read side, leaving
/// megabytes unread on the socket. Either way: fail typed, write
/// nothing.
pub(crate) fn too_large(what: &str, bytes: u64) -> WireError {
    WireError::Rejected {
        code: "too_large".into(),
        message: format!(
            "{what} of {bytes} bytes exceeds the frame cap ({MAX_FRAME})"),
    }
}

/// Build a `{"ok": false}` response carrying a machine code; `extra`
/// appends error-specific fields (e.g. the offending table name).
pub(crate) fn err_obj(code: &str, msg: &str, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("code", Json::str(code)),
        ("error", Json::str(msg)),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

/// The error frame for a typed [`WireError`], with its extra fields.
pub(crate) fn err_frame(e: &WireError) -> Json {
    let extra = match e {
        WireError::UnsupportedVersion { max } => {
            vec![("max_v", Json::num(*max as f64))]
        }
        WireError::NoSuchTable(t) | WireError::TableExists(t) => {
            vec![("table", Json::str(t.as_str()))]
        }
        _ => Vec::new(),
    };
    err_obj(e.code(), &e.to_string(), extra)
}

/// Resolve a request frame's protocol version: no `"v"` field means v1.
pub(crate) fn frame_version(j: &Json) -> Result<u64, WireError> {
    match j.get("v") {
        None => Ok(1),
        Some(v) => match v.as_f64() {
            Some(x) if x == 1.0 => Ok(1),
            Some(x) if x == 2.0 => Ok(2),
            _ => Err(WireError::UnsupportedVersion { max: VERSION }),
        },
    }
}

/// Strictly parse the request's `ids` array: every element must be a
/// non-negative integer JSON number. Anything else (negative, fractional,
/// string, null) returns `Ok(None)` so the caller can reject -- never
/// drop or saturate-clamp a malformed id (`-1 as usize` would silently
/// become id 0). A missing or non-array `ids` is an error.
pub(crate) fn parse_ids(j: &Json, op: &str) -> Result<Option<Vec<usize>>, WireError> {
    let arr = j
        .get("ids")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| WireError::Malformed(format!("{op} without ids")))?;
    Ok(arr
        .iter()
        .map(|x| match x.as_f64() {
            Some(n) if n >= 0.0
                && n.fract() == 0.0
                && n <= usize::MAX as f64 => Some(n as usize),
            _ => None,
        })
        .collect())
}

/// Strictly parse the request's `query` vector (the `score`/`topk`
/// ops): every element must be a JSON number that is finite AND stays
/// finite after the f32 cast. JSON has no NaN/Inf literals, but `1e999`
/// parses to +inf and `1e39` overflows f32 -- either would silently
/// poison every downstream score, so both are typed `malformed`
/// rejections HERE at the protocol layer, before any compute. Returns
/// `Ok(None)` when the frame has no `query` field (the caller may
/// accept a `query_id` instead); a present-but-invalid query is an
/// error.
pub(crate) fn parse_query(j: &Json, op: &str) -> Result<Option<Vec<f32>>, WireError> {
    let Some(q) = j.get("query") else {
        return Ok(None);
    };
    let arr = q.as_arr().ok_or_else(|| {
        WireError::Malformed(format!("{op} query is not an array"))
    })?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        let n = x.as_f64().ok_or_else(|| {
            WireError::Malformed(format!("{op} query[{i}] is not a number"))
        })?;
        let f = n as f32;
        if !n.is_finite() || !f.is_finite() {
            return Err(WireError::Malformed(format!(
                "{op} query[{i}] is not a finite f32")));
        }
        out.push(f);
    }
    Ok(Some(out))
}

// ---- framing helpers (shared by server and client) ----

/// Read one length-prefixed JSON frame (enforces the 64 MiB cap).
pub fn read_frame(stream: &mut TcpStream) -> Result<String, WireError> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(WireError::Malformed(format!("frame too large: {n}")));
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    String::from_utf8(buf)
        .map_err(|e| WireError::Malformed(format!("frame not utf-8: {e}")))
}

/// Write one length-prefixed JSON frame. Refuses payloads over
/// [`MAX_FRAME`] with a typed `too_large` error BEFORE any bytes hit
/// the sink -- the old `>= u32::MAX` guard still let a 65 MiB payload
/// through, which the peer's read side would refuse mid-stream.
/// Generic over the sink so the threaded plane (`TcpStream`), the
/// event plane (per-connection output buffers), and unit tests
/// (`Vec<u8>`) all share one implementation.
pub fn write_frame<W: Write + ?Sized>(w: &mut W, payload: &str) -> Result<(), WireError> {
    if payload.len() > MAX_FRAME {
        return Err(too_large("frame", payload.len() as u64));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload.as_bytes())?;
    Ok(())
}

/// How often the server-side frame reader wakes to re-check the stop
/// flag and its deadline while blocked on a quiet socket. On the event
/// plane this same slice is the `epoll_wait` timeout -- the one timer
/// in the whole connection plane.
pub(crate) const POLL_SLICE: Duration = Duration::from_millis(100);

/// Grace allowed to finish an in-flight frame once the server begins
/// draining (stop flag set): long enough for any legitimate in-transit
/// frame, short enough that shutdown join time stays bounded.
pub(crate) const DRAIN_GRACE: Duration = Duration::from_millis(250);

/// Outcome of a deadline-aware server-side frame read.
pub(crate) enum FrameIn {
    /// A complete frame payload (UTF-8 JSON text).
    Frame(String),
    /// The peer closed cleanly at a frame boundary.
    Eof,
    /// The server is draining (stop flag) and this connection is idle at
    /// a frame boundary -- close it without an error.
    Stopped,
    /// The idle or mid-frame deadline expired (`--conn-timeout`).
    TimedOut,
    /// The length prefix claims more than [`MAX_FRAME`] bytes. The
    /// payload was never read, so the stream CANNOT be resynced -- the
    /// caller answers typed and closes.
    TooLarge(u64),
    /// The payload was fully read but is not UTF-8. The stream is still
    /// in sync, so the caller can answer typed and keep the connection.
    NotUtf8(String),
}

/// Incremental-progress outcome of one `fill` call (see
/// [`DeadlineReader`]).
enum Step {
    Done,
    Eof,
    Stopped,
    TimedOut,
}

/// Deadline state for reading ONE frame: the deadline is ABSOLUTE from
/// the frame's first byte (`first_byte + timeout`), so a byte-at-a-time
/// slow-loris cannot reset it by trickling -- while a slow-but-legit
/// writer that completes its frame within the budget is served
/// normally. Before the first byte the same budget acts as the idle
/// deadline. Reads run in short [`POLL_SLICE`] slices so the stop flag
/// is observed within ~100ms even on a silent socket.
struct DeadlineReader<'a> {
    stream: &'a mut TcpStream,
    timeout: Option<Duration>,
    stop: &'a AtomicBool,
    started: Instant,
    first_byte: Option<Instant>,
    stop_seen: Option<Instant>,
}

impl<'a> DeadlineReader<'a> {
    fn new(
        stream: &'a mut TcpStream,
        timeout: Option<Duration>,
        stop: &'a AtomicBool,
    ) -> Self {
        DeadlineReader {
            stream,
            timeout,
            stop,
            started: Instant::now(),
            first_byte: None,
            stop_seen: None,
        }
    }

    /// Fill `buf` completely, or report why it could not be filled.
    /// `Eof`/`Stopped` are only possible before the frame's first byte;
    /// a peer vanishing mid-frame is an `Err` (nothing to answer to).
    fn fill(&mut self, buf: &mut [u8]) -> Result<Step, WireError> {
        let mut off = 0usize;
        while off < buf.len() {
            if self.stop_seen.is_none() && self.stop.load(Ordering::Relaxed) {
                self.stop_seen = Some(Instant::now());
                if self.first_byte.is_none() {
                    return Ok(Step::Stopped);
                }
            }
            let mut deadline = self
                .timeout
                .map(|t| self.first_byte.unwrap_or(self.started) + t);
            if let Some(s) = self.stop_seen {
                // draining: cap the remaining wait regardless of how
                // generous (or absent) the configured timeout is
                let drain = s + DRAIN_GRACE;
                deadline = Some(deadline.map_or(drain, |d| d.min(drain)));
            }
            let now = Instant::now();
            let wait = match deadline {
                Some(d) if now >= d => return Ok(Step::TimedOut),
                Some(d) => POLL_SLICE.min(d - now),
                None => POLL_SLICE,
            };
            self.stream
                .set_read_timeout(Some(wait.max(Duration::from_millis(1))))?;
            match self.stream.read(&mut buf[off..]) {
                Ok(0) => {
                    return if self.first_byte.is_none() {
                        Ok(Step::Eof)
                    } else {
                        Err(WireError::Io("peer closed mid-frame".into()))
                    };
                }
                Ok(k) => {
                    if self.first_byte.is_none() {
                        self.first_byte = Some(Instant::now());
                    }
                    off += k;
                }
                Err(e) if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(Step::Done)
    }
}

/// Server side: read one request frame under the connection deadline
/// discipline. Unlike [`read_frame`], the payload buffer grows only as
/// bytes actually arrive (64 KiB windows) -- a length-prefix lie of
/// "64 MiB follows" costs the server only what the peer really sends,
/// never an upfront allocation of the claimed size.
pub(crate) fn read_frame_deadline(
    stream: &mut TcpStream,
    timeout: Option<Duration>,
    stop: &AtomicBool,
) -> Result<FrameIn, WireError> {
    let mut r = DeadlineReader::new(stream, timeout, stop);
    let mut len4 = [0u8; 4];
    match r.fill(&mut len4)? {
        Step::Done => {}
        Step::Eof => return Ok(FrameIn::Eof),
        Step::Stopped => return Ok(FrameIn::Stopped),
        Step::TimedOut => return Ok(FrameIn::TimedOut),
    }
    let n = u32::from_le_bytes(len4) as usize;
    if n > MAX_FRAME {
        return Ok(FrameIn::TooLarge(n as u64));
    }
    const WINDOW: usize = 64 << 10;
    let mut buf: Vec<u8> = Vec::with_capacity(n.min(WINDOW));
    while buf.len() < n {
        let off = buf.len();
        let take = (n - off).min(WINDOW);
        buf.resize(off + take, 0);
        match r.fill(&mut buf[off..off + take])? {
            Step::Done => {}
            Step::TimedOut => return Ok(FrameIn::TimedOut),
            // unreachable once the prefix arrived (fill only reports
            // these before the frame's first byte); treat defensively
            // as a mid-frame close
            Step::Eof | Step::Stopped => {
                return Err(WireError::Io("peer closed mid-frame".into()));
            }
        }
    }
    match String::from_utf8(buf) {
        Ok(s) => Ok(FrameIn::Frame(s)),
        Err(e) => Ok(FrameIn::NotUtf8(format!("frame not utf-8: {e}"))),
    }
}

/// Server side: encode a binary lookup response. v2 frames are
/// self-describing (`u32 n | u32 d` header before the f32 rows); v1
/// frames keep the legacy headerless payload.
pub(crate) fn write_bin_rows<W: Write + ?Sized>(
    w: &mut W,
    version: u64,
    n: usize,
    d: usize,
    flat: &[f32],
) -> Result<(), WireError> {
    debug_assert_eq!(flat.len(), n * d);
    let header = if version >= 2 { 8u64 } else { 0 };
    let bytes = header + flat.len() as u64 * 4;
    // Enforce the SAME bound the client's read side enforces (MAX_FRAME,
    // not just the u32 prefix limit): a response the peer refuses to
    // read would leave megabytes unread on the socket and desync every
    // later frame on the connection. Typed, and BEFORE any bytes go out.
    if bytes > MAX_FRAME as u64 || n as u64 > u32::MAX as u64 || d as u64 > u32::MAX as u64 {
        return Err(too_large("lookup_bin response", bytes));
    }
    let mut payload = Vec::with_capacity(bytes as usize);
    if version >= 2 {
        payload.extend_from_slice(&(n as u32).to_le_bytes());
        payload.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for v in flat {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    Ok(())
}

/// Server side: reject a binary lookup. The `u32::MAX` sentinel can never
/// be a real frame length (an empty id list legitimately answers with a
/// zero-length v1 payload / 8-byte v2 header). Under v2 the sentinel is
/// followed by the caller-built JSON error frame (usually
/// [`err_frame`], possibly annotated -- e.g. `"evicted": true` on a
/// `no_such_table` rejection) so the rejection is self-describing; v1
/// keeps the bare sentinel.
pub(crate) fn write_bin_reject_frame<W: Write + ?Sized>(
    w: &mut W,
    version: u64,
    frame: &Json,
) -> Result<(), WireError> {
    w.write_all(&u32::MAX.to_le_bytes())?;
    if version >= 2 {
        write_frame(w, &frame.to_string())?;
    }
    Ok(())
}

/// Total payload bytes of a multi-section binary response over sections
/// of `(n, d)` rows; `None` when a section or the sum overflows.
pub(crate) fn sections_payload_bytes(
    sections: &[(usize, usize)],
) -> Option<u64> {
    let mut total = 4u64; // u32 section count
    for &(n, d) in sections {
        let rows = (n as u64).checked_mul(d as u64)?.checked_mul(4)?;
        total = total.checked_add(8)?.checked_add(rows)?;
    }
    Some(total)
}

/// Server side: encode a multi-section binary response (the
/// `lookup_fanout` op, v2-only). Layout after the u32 LE frame length:
/// a `u32 section_count`, then per section a `u32 n | u32 d` header
/// followed by `n*d` f32 LE row-major values -- every section
/// self-describing, sections in request order. The whole frame obeys the
/// same `MAX_FRAME` cap as every other response; callers pre-check via
/// [`sections_payload_bytes`] so nothing is written on the reject path.
pub(crate) fn write_bin_sections<W: Write + ?Sized>(
    w: &mut W,
    sections: &[(usize, usize, &[f32])],
) -> Result<(), WireError> {
    let dims: Vec<(usize, usize)> =
        sections.iter().map(|&(n, d, _)| (n, d)).collect();
    let bytes = sections_payload_bytes(&dims)
        .filter(|&b| b <= MAX_FRAME as u64)
        .ok_or_else(|| too_large(
            &format!("fan-out response over {} sections", sections.len()),
            sections_payload_bytes(&dims).unwrap_or(u64::MAX)))?;
    let payload = bin_sections_payload(sections)?;
    debug_assert_eq!(payload.len() as u64, bytes);
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    Ok(())
}

/// Build the multi-section binary payload WITHOUT the single-frame cap:
/// the streamed fan-out path uses this directly (the cap is the whole
/// reason streaming exists), while [`write_bin_sections`] caps it at
/// [`MAX_FRAME`] first. Overflow and u32-dim checks are kept either
/// way, so the layout itself can never lie.
pub(crate) fn bin_sections_payload(
    sections: &[(usize, usize, &[f32])],
) -> Result<Vec<u8>, WireError> {
    let dims: Vec<(usize, usize)> =
        sections.iter().map(|&(n, d, _)| (n, d)).collect();
    let bytes = sections_payload_bytes(&dims).ok_or_else(|| {
        WireError::Malformed("fan-out response size overflows u64".into())
    })?;
    if sections.len() as u64 > u32::MAX as u64
        || dims.iter().any(|&(n, d)| n as u64 > u32::MAX as u64
                                     || d as u64 > u32::MAX as u64)
    {
        return Err(WireError::Malformed(
            "fan-out section dims exceed u32".into()));
    }
    let mut payload = Vec::with_capacity(bytes as usize);
    payload.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for &(n, d, flat) in sections {
        debug_assert_eq!(flat.len(), n * d);
        payload.extend_from_slice(&(n as u32).to_le_bytes());
        payload.extend_from_slice(&(d as u32).to_le_bytes());
        for v in flat {
            payload.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(payload)
}

/// Server side: emit one complete streamed response -- the
/// [`STREAM_SENTINEL`] prefix, the payload in chunks of at most
/// [`STREAM_CHUNK`] bytes (each `u32 LE len` + bytes), a `u32 0`
/// end-of-data marker, then the typed JSON terminal frame
/// `{"ok":true,"bytes":<total>,"chunks":<count>}` the client verifies
/// against what it received. The payload itself may exceed
/// [`MAX_FRAME`]; no individual write ever does.
pub(crate) fn write_stream_payload<W: Write + ?Sized>(
    w: &mut W,
    payload: &[u8],
) -> Result<(), WireError> {
    w.write_all(&STREAM_SENTINEL.to_le_bytes())?;
    let mut chunks = 0u64;
    for chunk in payload.chunks(STREAM_CHUNK) {
        w.write_all(&(chunk.len() as u32).to_le_bytes())?;
        w.write_all(chunk)?;
        chunks += 1;
    }
    w.write_all(&0u32.to_le_bytes())?;
    write_frame(w, &Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("bytes", Json::num(payload.len() as f64)),
        ("chunks", Json::num(chunks as f64)),
    ]).to_string())
}

/// A lookup result: `n` rows of width `d`, flat row-major storage.
#[derive(Debug, Clone, PartialEq)]
pub struct Rows {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

impl Rows {
    pub(crate) fn new(n: usize, d: usize, data: Vec<f32>) -> Rows {
        debug_assert_eq!(data.len(), n * d);
        Rows { n, d, data }
    }

    /// Number of rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Embedding width (from the response header).
    pub fn d(&self) -> usize {
        self.d
    }

    /// True when the result holds no rows.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Row `i` as a `d`-length slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// All rows as one flat row-major slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Iterate rows as slices.
    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.d.max(1))
    }

    /// Convert into one `Vec<f32>` per row.
    pub fn into_vecs(self) -> Vec<Vec<f32>> {
        let d = self.d.max(1);
        self.data.chunks_exact(d).map(|r| r.to_vec()).collect()
    }
}

/// One served table as reported by the `tables` op.
#[derive(Debug, Clone, PartialEq)]
pub struct TableDesc {
    /// Registry name lookups route by.
    pub name: String,
    /// Backend scheme tag ("dpq", "dense", "scalar_quant", "low_rank").
    pub kind: String,
    /// Number of rows; valid ids are `0..vocab`.
    pub vocab: usize,
    /// Embedding width.
    pub d: usize,
    /// Inference-time storage in bits (codes + side tables).
    pub storage_bits: usize,
    /// Server-resident bytes (`storage_bits` rounded up to bytes), the
    /// unit the registry memory budget is enforced in.
    pub resident_bytes: usize,
    /// Compression ratio vs an f32 table of the same shape.
    pub compression_ratio: f64,
    /// Batcher shards range-partitioning each replica's id space.
    pub shards: usize,
    /// Independent batcher-shard replica sets serving this table
    /// (lookups route to the least-loaded one).
    pub replicas: usize,
    /// True for the table v1 (and table-less v2) frames route to.
    pub is_default: bool,
}

impl TableDesc {
    pub(crate) fn from_json(j: &Json, default_name: Option<&str>) -> Result<TableDesc, WireError> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| WireError::Malformed("table desc without name".into()))?
            .to_string();
        let get = |k: &str| j.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        Ok(TableDesc {
            is_default: default_name == Some(name.as_str()),
            kind: j.get("kind").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            vocab: get("vocab"),
            d: get("d"),
            storage_bits: get("storage_bits"),
            resident_bytes: get("resident_bytes"),
            compression_ratio: j
                .get("compression_ratio")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            shards: get("shards").max(1),
            replicas: get("replicas").max(1),
            name,
        })
    }
}

/// Blocking protocol-v2 client used by tests, benches, examples and the
/// CLI. Every lookup names its table; `tables()` and the `admin_*` ops
/// manage the server's registry hot.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a server (TCP_NODELAY on).
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self, WireError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// Connect with a read AND write deadline on every blocking socket
    /// operation: a wedged peer surfaces as a typed
    /// [`WireError::Timeout`] after `timeout` instead of hanging the
    /// caller forever. `repro hydrate` uses this -- pulling artifacts
    /// from a stalled replica must fail fast, not freeze provisioning.
    /// The deadline is per-syscall, not per-request: a large streamed
    /// response that keeps making progress never trips it.
    pub fn with_timeout(
        addr: std::net::SocketAddr,
        timeout: Duration,
    ) -> Result<Self, WireError> {
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        Ok(Client { stream })
    }

    /// Bound how long any single read on this client blocks (`None`
    /// blocks forever, the default). With a timeout set, a wedged or
    /// stalled server surfaces as a typed [`WireError::Io`] instead of
    /// hanging the caller -- the fuzzer's wedge detector is built on
    /// this.
    pub fn set_read_timeout(
        &self,
        timeout: Option<Duration>,
    ) -> Result<(), WireError> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Send one JSON request frame and parse the JSON response; a
    /// `{"ok": false}` response becomes a typed [`WireError`].
    fn request(&mut self, req: Json) -> Result<Json, WireError> {
        write_frame(&mut self.stream, &req.to_string())?;
        let j = Json::parse(&read_frame(&mut self.stream)?)
            .map_err(WireError::Malformed)?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(j)
        } else {
            Err(WireError::from_response(&j))
        }
    }

    fn lookup_req(op: &str, table: &str, ids: &[usize]) -> Json {
        Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str(op)),
            ("table", Json::str(table)),
            ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ])
    }

    /// JSON lookup against a named table.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use dpq_embed::server::Client;
    ///
    /// let mut c = Client::connect("127.0.0.1:7878".parse().unwrap())?;
    /// let rows = c.lookup("emb", &[0, 1, 2])?;
    /// assert_eq!(rows.n(), 3);
    /// for row in rows.iter() {
    ///     println!("{} values: {:?}", rows.d(), row);
    /// }
    /// # Ok::<(), dpq_embed::server::WireError>(())
    /// ```
    pub fn lookup(&mut self, table: &str, ids: &[usize]) -> Result<Rows, WireError> {
        let j = self.request(Self::lookup_req("lookup", table, ids))?;
        let vecs = j
            .get("vectors")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| WireError::Malformed("response without vectors".into()))?;
        let n = vecs.len();
        let d = j
            .get("d")
            .and_then(|v| v.as_usize())
            .or_else(|| vecs.first().and_then(|r| r.as_arr()).map(|r| r.len()))
            .unwrap_or(0);
        let mut data = Vec::with_capacity(n * d);
        for row in vecs {
            let row = row
                .as_arr()
                .ok_or_else(|| WireError::Malformed("vectors row not an array".into()))?;
            if row.len() != d {
                return Err(WireError::Malformed(format!(
                    "ragged response: row of {} values, d={d}", row.len())));
            }
            for x in row {
                data.push(x.as_f64().ok_or_else(|| {
                    WireError::Malformed("non-numeric vector entry".into())
                })? as f32);
            }
        }
        Ok(Rows::new(n, d, data))
    }

    /// Binary lookup: same semantics as [`lookup`](Self::lookup), raw
    /// f32-LE rows. The response's `(n, d)` header sizes the result -- the
    /// caller never passes (or guesses) the embedding width.
    pub fn lookup_bin(&mut self, table: &str, ids: &[usize]) -> Result<Rows, WireError> {
        write_frame(&mut self.stream,
                    &Self::lookup_req("lookup_bin", table, ids).to_string())?;
        self.read_bin_response()
    }

    /// Binary lookup straight into a caller buffer of `ids.len() * d`
    /// floats. Returns the table's `d`. If the buffer implies a different
    /// width than the response header, the error is a typed
    /// [`WireError::WidthMismatch`] -- and the payload is still drained,
    /// so the connection stays usable.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use dpq_embed::server::{Client, WireError};
    ///
    /// let mut c = Client::connect("127.0.0.1:7878".parse().unwrap())?;
    /// let ids = [3usize, 7, 11];
    /// // caller owns the buffer: d = 64 here, no per-call allocation
    /// let mut out = vec![0.0f32; ids.len() * 64];
    /// match c.lookup_into("emb", &ids, &mut out) {
    ///     Ok(d) => assert_eq!(d, 64),
    ///     // a wrong-width buffer is a typed error, not a truncated read
    ///     Err(WireError::WidthMismatch { expected, got }) => {
    ///         eprintln!("buffer sized for d={expected}, table has d={got}");
    ///     }
    ///     Err(e) => return Err(e),
    /// }
    /// # Ok::<(), dpq_embed::server::WireError>(())
    /// ```
    pub fn lookup_into(
        &mut self,
        table: &str,
        ids: &[usize],
        out: &mut [f32],
    ) -> Result<usize, WireError> {
        write_frame(&mut self.stream,
                    &Self::lookup_req("lookup_bin", table, ids).to_string())?;
        let rows = self.read_bin_response()?;
        if rows.n() != ids.len() {
            return Err(WireError::Malformed(format!(
                "server answered {} rows for {} ids", rows.n(), ids.len())));
        }
        if out.len() != rows.n() * rows.d() {
            let expected =
                if ids.is_empty() { 0 } else { out.len() / ids.len() };
            return Err(WireError::WidthMismatch { expected, got: rows.d() });
        }
        out.copy_from_slice(rows.as_slice());
        Ok(rows.d())
    }

    /// Read one binary response's payload, shared by every binary op:
    /// handles the `u32::MAX` rejection sentinel (decodes the JSON error
    /// frame that follows it into a typed error), reassembles a
    /// [`STREAM_SENTINEL`] chunked response, enforces the frame cap on
    /// single frames, and requires at least `min_len` bytes of header.
    fn read_bin_payload(
        &mut self,
        min_len: usize,
        what: &str,
    ) -> Result<Vec<u8>, WireError> {
        let mut len4 = [0u8; 4];
        self.stream.read_exact(&mut len4)?;
        let len32 = u32::from_le_bytes(len4);
        if len32 == u32::MAX {
            // rejection sentinel: a JSON error frame follows (v2)
            let j = Json::parse(&read_frame(&mut self.stream)?)
                .map_err(WireError::Malformed)?;
            return Err(WireError::from_response(&j));
        }
        let buf = if len32 == STREAM_SENTINEL {
            self.read_stream_payload()?
        } else {
            let len = len32 as usize;
            if len > MAX_FRAME {
                return Err(WireError::Malformed(format!(
                    "frame too large: {len}")));
            }
            let mut buf = vec![0u8; len];
            self.stream.read_exact(&mut buf)?;
            buf
        };
        if buf.len() < min_len {
            return Err(WireError::Malformed(format!(
                "{what} frame of {} bytes is shorter than its \
                 {min_len}-byte header", buf.len())));
        }
        Ok(buf)
    }

    /// Reassemble a streamed response after its [`STREAM_SENTINEL`]:
    /// data chunks (each at most [`STREAM_CHUNK`] bytes) until a zero
    /// length, then the typed JSON terminal frame, which must be
    /// `{"ok": true}` and agree with the received byte/chunk counts --
    /// a truncated or lying stream is a typed error, never a silently
    /// short payload. The assembled total may legitimately exceed
    /// [`MAX_FRAME`]; that is the point of streaming.
    fn read_stream_payload(&mut self) -> Result<Vec<u8>, WireError> {
        let mut buf = Vec::new();
        let mut chunks = 0u64;
        loop {
            let mut len4 = [0u8; 4];
            self.stream.read_exact(&mut len4)?;
            let len = u32::from_le_bytes(len4) as usize;
            if len == 0 {
                break;
            }
            if len > STREAM_CHUNK {
                return Err(WireError::Malformed(format!(
                    "streamed chunk of {len} bytes exceeds the chunk cap \
                     ({STREAM_CHUNK})")));
            }
            let off = buf.len();
            buf.resize(off + len, 0);
            self.stream.read_exact(&mut buf[off..])?;
            chunks += 1;
        }
        let j = Json::parse(&read_frame(&mut self.stream)?)
            .map_err(WireError::Malformed)?;
        if j.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            return Err(WireError::from_response(&j));
        }
        if j.get("bytes").and_then(|v| v.as_usize()) != Some(buf.len())
            || j.get("chunks").and_then(|v| v.as_usize())
                != Some(chunks as usize)
        {
            return Err(WireError::Malformed(format!(
                "stream terminal frame does not match the received data \
                 ({} bytes in {chunks} chunks)", buf.len())));
        }
        Ok(buf)
    }

    fn read_bin_response(&mut self) -> Result<Rows, WireError> {
        let buf = self.read_bin_payload(8, "binary lookup")?;
        let len = buf.len();
        let n = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        let d = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        if len != 8 + n * d * 4 {
            return Err(WireError::Malformed(format!(
                "binary frame of {len} bytes does not match header n={n} d={d}")));
        }
        let data = buf[8..]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Rows::new(n, d, data))
    }

    /// Cross-table fan-out: one request frame carrying `(table, ids)`
    /// pairs, answered as ONE multi-section binary response -- a
    /// recommender-style "user + item + context" lookup costs a single
    /// round trip instead of one per table. Sections come back in
    /// request order, each self-describing (`(n, d)` header), and each
    /// is bit-identical to what a per-table
    /// [`lookup_bin`](Self::lookup_bin) would have returned. The op is
    /// all-or-nothing: any unknown table or out-of-range id rejects the
    /// whole frame, typed.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use dpq_embed::server::Client;
    ///
    /// let mut c = Client::connect("127.0.0.1:7878".parse().unwrap())?;
    /// let sections = c.lookup_fanout(&[
    ///     ("user", &[42][..]),
    ///     ("item", &[7, 9, 11][..]),
    /// ])?;
    /// assert_eq!(sections.len(), 2);
    /// assert_eq!(sections[1].n(), 3);
    /// # Ok::<(), dpq_embed::server::WireError>(())
    /// ```
    pub fn lookup_fanout(
        &mut self,
        queries: &[(&str, &[usize])],
    ) -> Result<Vec<Rows>, WireError> {
        self.fanout_req(queries, false)
    }

    /// Like [`lookup_fanout`](Self::lookup_fanout), but asks the server
    /// to stream the multi-section response in bounded chunks
    /// (`"stream": true`), so the combined result may exceed the single
    /// frame cap. Section bytes are identical to the unstreamed path.
    pub fn lookup_fanout_stream(
        &mut self,
        queries: &[(&str, &[usize])],
    ) -> Result<Vec<Rows>, WireError> {
        self.fanout_req(queries, true)
    }

    fn fanout_req(
        &mut self,
        queries: &[(&str, &[usize])],
        stream: bool,
    ) -> Result<Vec<Rows>, WireError> {
        let qs = Json::arr(
            queries
                .iter()
                .map(|(t, ids)| Json::obj(vec![
                    ("table", Json::str(*t)),
                    ("ids", Json::arr(
                        ids.iter().map(|&i| Json::num(i as f64)).collect())),
                ]))
                .collect(),
        );
        let mut pairs = vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("lookup_fanout")),
            ("queries", qs),
        ];
        if stream {
            pairs.push(("stream", Json::Bool(true)));
        }
        write_frame(&mut self.stream, &Json::obj(pairs).to_string())?;
        let buf = self.read_bin_payload(4, "fan-out")?;
        let len = buf.len();
        let s = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        let mut off = 4usize;
        let mut out = Vec::with_capacity(s.min(1024));
        for k in 0..s {
            if off + 8 > len {
                return Err(WireError::Malformed(format!(
                    "fan-out frame truncated in section {k}'s header")));
            }
            let n = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
                as usize;
            let d = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap())
                as usize;
            off += 8;
            let bytes = (n as u64)
                .checked_mul(d as u64)
                .and_then(|x| x.checked_mul(4))
                .filter(|&b| off as u64 + b <= len as u64)
                .ok_or_else(|| WireError::Malformed(format!(
                    "fan-out section {k} (n={n}, d={d}) overruns the frame")))?
                as usize;
            let data = buf[off..off + bytes]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            out.push(Rows::new(n, d, data));
            off += bytes;
        }
        if off != len {
            return Err(WireError::Malformed(format!(
                "fan-out frame has {} trailing bytes after {s} sections",
                len - off)));
        }
        Ok(out)
    }

    fn query_json(query: &[f32]) -> Json {
        Json::arr(query.iter().map(|&x| Json::num(x as f64)).collect())
    }

    /// Decode a `scores` array of finite numbers from a response.
    fn scores_from(j: &Json, n_expected: Option<usize>) -> Result<Vec<f32>, WireError> {
        let arr = j
            .get("scores")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| WireError::Malformed("response without scores".into()))?;
        if let Some(n) = n_expected {
            if arr.len() != n {
                return Err(WireError::Malformed(format!(
                    "server answered {} scores for {n} candidates", arr.len())));
            }
        }
        arr.iter()
            .map(|x| {
                x.as_f64().map(|v| v as f32).ok_or_else(|| {
                    WireError::Malformed("non-numeric score entry".into())
                })
            })
            .collect()
    }

    /// Score an explicit candidate id list against a query vector,
    /// computed on the server directly over the table's compressed
    /// representation (the ADC lookup-table path for `dpq` /
    /// `scalar_quant`). Returns one dot-product score per id, in id-list
    /// order. Typed rejections: `width_mismatch` (query width != table
    /// `d`), `bad_ids`, `malformed` (non-finite query values),
    /// `score_unsupported` (backend kind without the capability).
    pub fn score(
        &mut self,
        table: &str,
        query: &[f32],
        ids: &[usize],
    ) -> Result<Vec<f32>, WireError> {
        let mut req = Self::lookup_req("score", table, ids);
        if let Json::Obj(m) = &mut req {
            m.insert("query".into(), Self::query_json(query));
        }
        let j = self.request(req)?;
        Self::scores_from(&j, Some(ids.len()))
    }

    /// Like [`score`](Self::score), but the query is a resident row of
    /// the SAME table (`query_id`): "how similar is everything in `ids`
    /// to item `query_id`" without the client ever holding a vector.
    pub fn score_with_id(
        &mut self,
        table: &str,
        query_id: usize,
        ids: &[usize],
    ) -> Result<Vec<f32>, WireError> {
        let mut req = Self::lookup_req("score", table, ids);
        if let Json::Obj(m) = &mut req {
            m.insert("query_id".into(), Json::num(query_id as f64));
        }
        let j = self.request(req)?;
        Self::scores_from(&j, Some(ids.len()))
    }

    /// Top-k most-similar rows to a query vector over the whole table
    /// (or over `lo..hi` when `range` is given), best first, ties broken
    /// by ascending id. Returns `(id, score)` pairs -- at most
    /// `min(k, range len)` of them. Typed rejections: `bad_k` (k = 0 or
    /// k > vocab), `bad_range`, `width_mismatch`, `malformed`
    /// (non-finite query values).
    ///
    /// # Example
    ///
    /// ```no_run
    /// use dpq_embed::server::Client;
    ///
    /// let mut c = Client::connect("127.0.0.1:7878".parse().unwrap())?;
    /// let query = vec![0.25f32; 64];
    /// for (id, score) in c.topk("emb", &query, 5, None)? {
    ///     println!("id {id}: {score:+.4}");
    /// }
    /// # Ok::<(), dpq_embed::server::WireError>(())
    /// ```
    pub fn topk(
        &mut self,
        table: &str,
        query: &[f32],
        k: usize,
        range: Option<(usize, usize)>,
    ) -> Result<Vec<(usize, f32)>, WireError> {
        let mut pairs = vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("topk")),
            ("table", Json::str(table)),
            ("query", Self::query_json(query)),
            ("k", Json::num(k as f64)),
        ];
        if let Some((lo, hi)) = range {
            pairs.push(("lo", Json::num(lo as f64)));
            pairs.push(("hi", Json::num(hi as f64)));
        }
        let j = self.request(Json::obj(pairs))?;
        Self::topk_from(&j)
    }

    /// Like [`topk`](Self::topk), but asks the server to answer with a
    /// streamed **binary** payload (`"stream": true`): a `u64 LE n`
    /// header, then `n` u64 LE ids, then `n` f32 LE scores, delivered
    /// in bounded chunks. This lifts the single-frame cap -- a
    /// full-vocab scan (`k = vocab`) that the JSON path rejects as
    /// `too_large` streams fine -- while ranking semantics (best first,
    /// ties by ascending id) stay identical to the unstreamed op.
    pub fn topk_stream(
        &mut self,
        table: &str,
        query: &[f32],
        k: usize,
        range: Option<(usize, usize)>,
    ) -> Result<Vec<(usize, f32)>, WireError> {
        let mut pairs = vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("topk")),
            ("table", Json::str(table)),
            ("query", Self::query_json(query)),
            ("k", Json::num(k as f64)),
            ("stream", Json::Bool(true)),
        ];
        if let Some((lo, hi)) = range {
            pairs.push(("lo", Json::num(lo as f64)));
            pairs.push(("hi", Json::num(hi as f64)));
        }
        write_frame(&mut self.stream, &Json::obj(pairs).to_string())?;
        let buf = self.read_bin_payload(8, "streamed topk")?;
        let n = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let need = n
            .checked_mul(12)
            .and_then(|b| b.checked_add(8))
            .filter(|&b| b == buf.len() as u64)
            .ok_or_else(|| WireError::Malformed(format!(
                "streamed topk payload of {} bytes does not match its \
                 n={n} header", buf.len())))?;
        let _ = need;
        let n = n as usize;
        let ids_end = 8 + n * 8;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let id = u64::from_le_bytes(
                buf[8 + i * 8..16 + i * 8].try_into().unwrap());
            let score = f32::from_le_bytes(
                buf[ids_end + i * 4..ids_end + 4 + i * 4].try_into().unwrap());
            out.push((id as usize, score));
        }
        Ok(out)
    }

    /// Like [`topk`](Self::topk), but the query is a resident row of the
    /// SAME table (`query_id`): "the k items most like item `query_id`"
    /// without the client ever holding a vector. The query row itself is
    /// in the candidate set, so it comes back ranked (first, unless the
    /// range excludes it).
    pub fn topk_by_id(
        &mut self,
        table: &str,
        query_id: usize,
        k: usize,
        range: Option<(usize, usize)>,
    ) -> Result<Vec<(usize, f32)>, WireError> {
        let mut pairs = vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("topk")),
            ("table", Json::str(table)),
            ("query_id", Json::num(query_id as f64)),
            ("k", Json::num(k as f64)),
        ];
        if let Some((lo, hi)) = range {
            pairs.push(("lo", Json::num(lo as f64)));
            pairs.push(("hi", Json::num(hi as f64)));
        }
        let j = self.request(Json::obj(pairs))?;
        Self::topk_from(&j)
    }

    /// Decode a topk response into `(id, score)` pairs, best first.
    fn topk_from(j: &Json) -> Result<Vec<(usize, f32)>, WireError> {
        let ids = j
            .get("ids")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| WireError::Malformed("topk response without ids".into()))?
            .iter()
            .map(|x| {
                x.as_usize().ok_or_else(|| {
                    WireError::Malformed("non-integer topk id".into())
                })
            })
            .collect::<Result<Vec<usize>, WireError>>()?;
        let scores = Self::scores_from(j, Some(ids.len()))?;
        Ok(ids.into_iter().zip(scores).collect())
    }

    /// Ask the server to snapshot its whole registry into the
    /// **server-side** directory `dir` (artifact files + versioned
    /// manifest); returns the manifest path on the server's filesystem.
    /// `repro serve --restore <manifest>` rebuilds the registry from it.
    pub fn admin_snapshot(&mut self, dir: &str) -> Result<String, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("snapshot")),
            ("dir", Json::str(dir)),
        ]))?;
        j.get("manifest")
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| {
                WireError::Malformed("snapshot response without manifest".into())
            })
    }

    /// Fetch a spill artifact's raw bytes by content digest (64-hex
    /// SHA-256), answered as a chunked stream. The server re-hashes the
    /// file before serving, so the returned bytes always match the
    /// requested digest -- but the caller should verify again after the
    /// transfer (the wire is not the only thing that can lie). Typed
    /// rejections: `not_found` (no spilled artifact with that digest,
    /// or its on-disk bytes no longer hash to it), `bad_digest`
    /// (malformed digest string).
    pub fn fetch_artifact(&mut self, sha256: &str) -> Result<Vec<u8>, WireError> {
        write_frame(&mut self.stream, &Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("fetch_artifact")),
            ("sha256", Json::str(sha256)),
        ]).to_string())?;
        self.read_bin_payload(0, "artifact")
    }

    /// List the served tables (name, kind, shape, storage, default flag).
    pub fn tables(&mut self) -> Result<Vec<TableDesc>, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("tables")),
        ]))?;
        let default = j.get("default").and_then(|v| v.as_str()).map(str::to_string);
        j.get("tables")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| WireError::Malformed("response without tables".into()))?
            .iter()
            .map(|t| TableDesc::from_json(t, default.as_deref()))
            .collect()
    }

    /// Names of the peer's SPILLED tables (the `tables` op's `spilled`
    /// listing -- resident tables come back from [`Client::tables`]).
    /// Full per-table detail, including the spill artifact's content
    /// digest, comes from [`Client::stats`].
    pub fn spilled_tables(&mut self) -> Result<Vec<String>, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("tables")),
        ]))?;
        Ok(j.get("spilled")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default())
    }

    /// Per-table serving stats; `table` narrows to one table's flat
    /// object, `None` returns the aggregate plus a per-table map.
    pub fn stats(&mut self, table: Option<&str>) -> Result<Json, WireError> {
        let mut pairs = vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("stats")),
        ];
        if let Some(t) = table {
            pairs.push(("table", Json::str(t)));
        }
        self.request(Json::obj(pairs))
    }

    /// Hot-load a `.dpq` artifact from a server-side path as a new table.
    pub fn admin_load(&mut self, table: &str, path: &str) -> Result<TableDesc, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("load")),
            ("table", Json::str(table)),
            ("path", Json::str(path)),
        ]))?;
        let desc = j
            .get("table")
            .ok_or_else(|| WireError::Malformed("load response without table".into()))?;
        TableDesc::from_json(desc, j.get("default").and_then(|v| v.as_str()))
    }

    /// Hot-unload a table; its in-flight lookups fail typed, later
    /// lookups get [`WireError::NoSuchTable`]. A SPILLED table can be
    /// unloaded too (its spill artifact is garbage-collected).
    pub fn admin_unload(&mut self, table: &str) -> Result<(), WireError> {
        self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("unload")),
            ("table", Json::str(table)),
        ]))?;
        Ok(())
    }

    /// Demote a resident table to the server's spill tier (`--spill-dir`):
    /// its memory is released and the NEXT lookup to it transparently
    /// reloads it. Returns the spill artifact's file name on the server.
    /// Typed rejections: `spill_disabled` (server has no spill tier),
    /// `not_resident` (already spilled), `no_such_table`, `demote_failed`
    /// (artifact write failed -- the table stays resident and serving).
    pub fn admin_demote(&mut self, table: &str) -> Result<String, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("demote")),
            ("table", Json::str(table)),
        ]))?;
        j.get("file")
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| {
                WireError::Malformed("demote response without file".into())
            })
    }

    /// Live-resize a table's batcher-shard replica count. A resident
    /// table is swapped to `n` fresh replica shard sets over the same
    /// backend (bit-identical bytes; mid-flight lookups are retried
    /// server-side, so traffic never observes the swap); a spilled
    /// table records `n` for its next promotion. Returns the replica
    /// count now in force. Typed rejections: `bad_replicas` (out of
    /// range), `no_such_table`.
    pub fn admin_set_replicas(
        &mut self,
        table: &str,
        n: usize,
    ) -> Result<usize, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("set_replicas")),
            ("table", Json::str(table)),
            ("replicas", Json::num(n as f64)),
        ]))?;
        j.get("replicas")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| {
                WireError::Malformed(
                    "set_replicas response without replicas".into())
            })
    }

    /// Live-resize a table's hot-row cache byte cap (0 disables and
    /// drops every cached row). A resident table trims immediately and
    /// re-enforces the memory budget, so the returned capacity-in-force
    /// may be smaller than requested; a spilled table records the cap
    /// for its next promotion. Typed rejection: `no_such_table`.
    pub fn admin_set_row_cache(
        &mut self,
        table: &str,
        bytes: u64,
    ) -> Result<u64, WireError> {
        let j = self.request(Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("set_row_cache")),
            ("table", Json::str(table)),
            ("bytes", Json::num(bytes as f64)),
        ]))?;
        j.get("row_cache_cap_bytes")
            .and_then(|v| v.as_usize())
            .map(|n| n as u64)
            .ok_or_else(|| {
                WireError::Malformed(
                    "set_row_cache response without row_cache_cap_bytes"
                        .into())
            })
    }

    /// Ask the server to exit (drains the acknowledgement).
    pub fn shutdown(&mut self) -> Result<(), WireError> {
        write_frame(&mut self.stream, &Json::obj(vec![
            ("v", Json::num(VERSION as f64)),
            ("op", Json::str("shutdown")),
        ]).to_string())?;
        let _ = read_frame(&mut self.stream);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_version_resolution() {
        let v1 = Json::parse(r#"{"op":"lookup","ids":[]}"#).unwrap();
        assert_eq!(frame_version(&v1).unwrap(), 1);
        let v1x = Json::parse(r#"{"v":1,"op":"lookup"}"#).unwrap();
        assert_eq!(frame_version(&v1x).unwrap(), 1);
        let v2 = Json::parse(r#"{"v":2,"op":"lookup"}"#).unwrap();
        assert_eq!(frame_version(&v2).unwrap(), 2);
        for bad in [r#"{"v":3}"#, r#"{"v":0}"#, r#"{"v":1.5}"#, r#"{"v":"2"}"#] {
            let j = Json::parse(bad).unwrap();
            assert_eq!(
                frame_version(&j).unwrap_err(),
                WireError::UnsupportedVersion { max: VERSION },
                "{bad}"
            );
        }
    }

    #[test]
    fn parse_ids_strict() {
        let ok = Json::parse(r#"{"ids":[0,3,12]}"#).unwrap();
        assert_eq!(parse_ids(&ok, "lookup").unwrap(), Some(vec![0, 3, 12]));
        for bad in [r#"{"ids":[1,-2]}"#, r#"{"ids":[1.5]}"#, r#"{"ids":["3"]}"#,
                    r#"{"ids":[null]}"#] {
            let j = Json::parse(bad).unwrap();
            assert_eq!(parse_ids(&j, "lookup").unwrap(), None, "{bad}");
        }
        let missing = Json::parse(r#"{"op":"lookup"}"#).unwrap();
        assert!(parse_ids(&missing, "lookup").is_err());
    }

    /// The non-finite fix: JSON has no NaN/Inf literals, but `1e999`
    /// parses to +inf and `1e39` is finite as f64 yet overflows f32 --
    /// both must be typed `malformed` rejections, never a NaN/Inf score.
    #[test]
    fn parse_query_rejects_non_finite_and_overflow() {
        let ok = Json::parse(r#"{"query":[0.5,-1,3e4]}"#).unwrap();
        assert_eq!(
            parse_query(&ok, "score").unwrap(),
            Some(vec![0.5f32, -1.0, 3e4])
        );
        let missing = Json::parse(r#"{"op":"score"}"#).unwrap();
        assert_eq!(parse_query(&missing, "score").unwrap(), None);
        for bad in [
            r#"{"query":[1e999]}"#,      // f64 +inf
            r#"{"query":[-1e999]}"#,     // f64 -inf
            r#"{"query":[1e39]}"#,       // finite f64, overflows f32
            r#"{"query":[-3.5e38]}"#,    // overflows f32 negative
            r#"{"query":[1,"x"]}"#,      // non-number entry
            r#"{"query":7}"#,            // not an array
        ] {
            let j = Json::parse(bad).unwrap();
            let e = parse_query(&j, "score").unwrap_err();
            assert_eq!(e.code(), "malformed", "{bad} -> {e}");
        }
    }

    #[test]
    fn wire_error_roundtrips_through_frames() {
        for e in [
            WireError::NoSuchTable("emb".into()),
            WireError::TableExists("emb".into()),
            WireError::UnsupportedVersion { max: VERSION },
            WireError::Rejected { code: "bad_ids".into(),
                                  message: "ids must be integers".into() },
        ] {
            let frame = err_frame(&e);
            assert_eq!(frame.get("ok").and_then(|v| v.as_bool()), Some(false));
            let back = WireError::from_response(&frame);
            match (&e, &back) {
                (WireError::Rejected { code: a, .. },
                 WireError::Rejected { code: b, .. }) => assert_eq!(a, b),
                _ => assert_eq!(e, back),
            }
        }
    }

    /// Build a loopback (server-side stream, client) pair for decode
    /// tests without a real server.
    fn pipe() -> (TcpStream, Client) {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let t = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = l.accept().unwrap();
        (srv, Client { stream: t.join().unwrap() })
    }

    /// The satellite bugfix: every writer must refuse an over-cap
    /// payload with a typed `too_large` error BEFORE any bytes hit the
    /// sink -- the old guard only caught `>= u32::MAX`, so a 65 MiB
    /// payload went out and desynced the peer mid-read.
    #[test]
    fn write_frame_rejects_oversize_typed_before_any_bytes() {
        let mut sink: Vec<u8> = Vec::new();
        let big = "x".repeat(MAX_FRAME + 1);
        let e = write_frame(&mut sink, &big).unwrap_err();
        assert_eq!(e.code(), "too_large");
        assert!(sink.is_empty(), "bytes escaped before the guard");

        write_frame(&mut sink, "{\"ok\":true}").unwrap();
        assert_eq!(&sink[..4], &(11u32).to_le_bytes());
        assert_eq!(&sink[4..], b"{\"ok\":true}");
    }

    #[test]
    fn bin_writers_reject_oversize_typed_before_any_bytes() {
        // 8-byte v2 header + (16 Mi + 1) * 4 bytes of rows > 64 MiB cap
        let n = (16 << 20) + 1;
        let flat = vec![0f32; n];
        let mut sink: Vec<u8> = Vec::new();
        let e = write_bin_rows(&mut sink, 2, n, 1, &flat).unwrap_err();
        assert_eq!(e.code(), "too_large");
        assert!(sink.is_empty());

        let e = write_bin_sections(&mut sink, &[(n, 1, &flat[..])])
            .unwrap_err();
        assert_eq!(e.code(), "too_large");
        assert!(sink.is_empty());

        // the same sections stream fine: no single-frame cap applies
        let payload = bin_sections_payload(&[(n, 1, &flat[..])]).unwrap();
        assert_eq!(payload.len(), 4 + 8 + n * 4);
    }

    #[test]
    fn streamed_payload_roundtrips_through_client_decode() {
        let (mut srv, mut client) = pipe();
        let payload: Vec<u8> =
            (0..STREAM_CHUNK * 2 + 123).map(|i| (i % 251) as u8).collect();
        let expect = payload.clone();
        let t = std::thread::spawn(move || {
            write_stream_payload(&mut srv, &payload).unwrap();
        });
        let got = client.read_bin_payload(1, "test").unwrap();
        t.join().unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn streamed_empty_payload_is_zero_chunks() {
        let (mut srv, mut client) = pipe();
        write_stream_payload(&mut srv, &[]).unwrap();
        let got = client.read_bin_payload(0, "test").unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn streamed_error_terminal_is_typed() {
        let (mut srv, mut client) = pipe();
        srv.write_all(&STREAM_SENTINEL.to_le_bytes()).unwrap();
        srv.write_all(&(3u32).to_le_bytes()).unwrap();
        srv.write_all(b"abc").unwrap();
        srv.write_all(&0u32.to_le_bytes()).unwrap();
        write_frame(&mut srv, &err_obj(
            "artifact_failed", "disk vanished mid-stream", vec![],
        ).to_string()).unwrap();
        let e = client.read_bin_payload(0, "test").unwrap_err();
        assert_eq!(e.code(), "artifact_failed");
    }

    #[test]
    fn streamed_chunk_over_cap_is_malformed() {
        let (mut srv, mut client) = pipe();
        srv.write_all(&STREAM_SENTINEL.to_le_bytes()).unwrap();
        srv.write_all(&((STREAM_CHUNK as u32) + 1).to_le_bytes()).unwrap();
        let e = client.read_bin_payload(0, "test").unwrap_err();
        assert_eq!(e.code(), "malformed", "{e}");
    }

    #[test]
    fn streamed_terminal_mismatch_is_malformed() {
        let (mut srv, mut client) = pipe();
        srv.write_all(&STREAM_SENTINEL.to_le_bytes()).unwrap();
        srv.write_all(&(3u32).to_le_bytes()).unwrap();
        srv.write_all(b"abc").unwrap();
        srv.write_all(&0u32.to_le_bytes()).unwrap();
        // terminal lies about the byte count
        write_frame(&mut srv, &Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("bytes", Json::num(99.0)),
            ("chunks", Json::num(1.0)),
        ]).to_string()).unwrap();
        let e = client.read_bin_payload(0, "test").unwrap_err();
        assert_eq!(e.code(), "malformed", "{e}");
    }

    #[test]
    fn rows_accessors() {
        let r = Rows::new(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(r.n(), 3);
        assert_eq!(r.d(), 2);
        assert_eq!(r.row(1), &[3.0, 4.0]);
        assert_eq!(r.iter().count(), 3);
        assert_eq!(r.clone().into_vecs()[2], vec![5.0, 6.0]);
        let empty = Rows::new(0, 0, vec![]);
        assert!(empty.is_empty());
        assert_eq!(empty.into_vecs().len(), 0);
    }
}
