//! Micro-batching primitives: the pending-lookup queue each batcher shard
//! drains, the zero-copy result views handed back to connection handlers,
//! and the batch runner that reconstructs one drained micro-batch through
//! an [`EmbeddingBackend`] on the shared worker pool.
//!
//! A [`BatchQueue`] owns its closed flag *inside* the queue mutex: `push`
//! observes close atomically with enqueue, and [`BatchQueue::close`]
//! drains-and-fails everything still queued under the same lock, so no
//! pending lookup can be stranded between a shard shutting down and a
//! handler enqueueing -- a handler blocked on its condvar is always
//! answered, with rows or with failure.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::backend::EmbeddingBackend;
use crate::server::row_cache::RowCache;
use crate::server::stats::Stats;

/// Lock a queue/slot mutex, recovering the guard if a previous holder
/// panicked. Every state these mutexes protect (a `VecDeque` + flag, an
/// `Option` slot) is valid at every interruptible point, so a poisoned
/// lock carries no torn data -- but an `unwrap()` here would wedge the
/// shard (or the waiting connection handler) FOREVER on the first
/// poison, turning one isolated panic into a dead table.
fn lock_recover<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A request's reconstructed rows: a shared view into its micro-batch's
/// flat buffer (row-major, `len` = ids * d). No per-request copy is made;
/// the buffer is freed when the last handler finishes encoding its view.
pub(crate) struct RowsSlice {
    buf: Arc<Vec<f32>>,
    start: usize,
    len: usize,
}

impl RowsSlice {
    pub(crate) fn as_slice(&self) -> &[f32] {
        &self.buf[self.start..self.start + self.len]
    }
}

/// Completion slot a handler waits on: filled exactly once by a batcher
/// shard (or by the failure path) with the request's [`RowsSlice`].
pub(crate) type DoneSlot = (Mutex<Option<RowsSlice>>, Condvar);

/// A pending lookup: ids + completion slot. Ids are validated against the
/// table's vocab by the connection handler BEFORE queueing -- the batcher
/// reconstructs unchecked (with a defensive release-build guard).
pub(crate) struct Pending {
    pub ids: Vec<usize>,
    pub done: Arc<DoneSlot>,
}

impl Pending {
    /// Build a pending lookup plus the slot its submitter will wait on.
    pub(crate) fn new(ids: Vec<usize>) -> (Pending, Arc<DoneSlot>) {
        let done = Arc::new((Mutex::new(None), Condvar::new()));
        (Pending { ids, done: done.clone() }, done)
    }

    pub(crate) fn complete(&self, rows: RowsSlice) {
        let (slot, cv) = &*self.done;
        *lock_recover(slot) = Some(rows);
        cv.notify_one();
    }

    /// Answer with an empty view: the submitter sees a length mismatch
    /// (it never enqueues empty id lists) and reports a typed error.
    pub(crate) fn fail(&self) {
        self.complete(RowsSlice { buf: Arc::new(Vec::new()), start: 0, len: 0 });
    }
}

/// Block until the slot is filled and take the result.
pub(crate) fn wait_rows(done: &DoneSlot) -> RowsSlice {
    let (slot, cv) = done;
    let mut guard = lock_recover(slot);
    while guard.is_none() {
        guard = cv.wait(guard).unwrap_or_else(PoisonError::into_inner);
    }
    guard.take().unwrap()
}

/// A request's assembled answer: either a zero-copy view of one shard's
/// batch buffer (single-shard fast path) or an owned buffer stitched from
/// several shards' views in id order.
pub(crate) enum Answer {
    View(RowsSlice),
    Owned(Vec<f32>),
}

impl Answer {
    pub(crate) fn as_slice(&self) -> &[f32] {
        match self {
            Answer::View(v) => v.as_slice(),
            Answer::Owned(v) => v,
        }
    }
}

struct QueueInner {
    q: VecDeque<Pending>,
    closed: bool,
}

/// Micro-batching queue: one per batcher shard. Lookups accumulate here;
/// the shard's batcher thread drains up to `max_batch` at a time.
pub struct BatchQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    /// Max pending lookups drained into one micro-batch.
    pub max_batch: usize,
}

impl BatchQueue {
    /// Open queue draining up to `max_batch` (min 1) per pop.
    pub fn new(max_batch: usize) -> Self {
        BatchQueue {
            inner: Mutex::new(QueueInner { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
        }
    }

    /// Enqueue, or -- if the queue is closed -- fail the pending lookup
    /// immediately and return false. The closed check happens under the
    /// queue lock, so a push can never race past [`close`](Self::close)'s
    /// drain and strand a waiter.
    pub(crate) fn push(&self, p: Pending) -> bool {
        let mut g = lock_recover(&self.inner);
        if g.closed {
            drop(g);
            p.fail();
            return false;
        }
        g.q.push_back(p);
        self.cv.notify_one();
        true
    }

    /// Pop up to max_batch entries, waiting up to `timeout` for the first.
    /// Recovers from a poisoned lock: a producer that panicked while
    /// holding the queue mutex must not wedge the shard's batcher thread
    /// permanently (the queue state itself is never torn -- see
    /// [`lock_recover`]).
    pub(crate) fn pop_batch(&self, timeout: Duration) -> Vec<Pending> {
        let mut g = lock_recover(&self.inner);
        if g.q.is_empty() && !g.closed {
            let (gg, _) = self
                .cv
                .wait_timeout(g, timeout)
                .unwrap_or_else(PoisonError::into_inner);
            g = gg;
        }
        let take = g.q.len().min(self.max_batch);
        g.q.drain(..take).collect()
    }

    /// Close the queue (idempotent): every still-queued pending lookup is
    /// failed, every later push fails fast, and the shard's batcher
    /// thread observes [`is_closed`](Self::is_closed) and exits.
    pub fn close(&self) {
        let rest: Vec<Pending> = {
            let mut g = lock_recover(&self.inner);
            g.closed = true;
            self.cv.notify_all();
            g.q.drain(..).collect()
        };
        for p in &rest {
            p.fail();
        }
    }

    /// True once [`close`](Self::close) has run.
    pub fn is_closed(&self) -> bool {
        lock_recover(&self.inner).closed
    }
}

/// Reconstruct one drained micro-batch: every request's ids concatenated,
/// decoded into a single flat row-major [total, d] buffer sharded across
/// the worker pool (small batches run serial -- a thread spawn costs more
/// than a few hundred row gathers), then handed back per request in queue
/// order as contiguous slices. Each row's gather is independent of which
/// chunk it lands in, so the served bits never depend on the thread
/// count. Batch wall-clock time lands in the table's latency ring.
///
/// With the table's hot-row `cache` enabled, each id is probed first: a
/// hit is a memcpy into the flat buffer, and only the misses go through
/// `reconstruct_rows_into` (then get admitted). Served bits are
/// IDENTICAL either way -- a cached row is a verbatim copy of a
/// deterministic reconstruction -- which `tests/cache_equivalence.rs`
/// pins against a cache-disabled twin.
pub(crate) fn run_batch(
    backend: &dyn EmbeddingBackend,
    batch: &[Pending],
    stats: &Stats,
    cache: &RowCache,
) {
    let t0 = Instant::now();
    let d = backend.d();
    let total: usize = batch.iter().map(|p| p.ids.len()).sum();
    let mut all_ids: Vec<usize> = Vec::with_capacity(total);
    for p in batch {
        all_ids.extend_from_slice(&p.ids);
    }
    // Handlers validate before queueing, so an out-of-range id here is a
    // bug -- but an OOB panic (or an assert) would kill the batcher
    // thread and leave every waiting handler blocked on its condvar
    // forever. Keep the server alive in every build: log loudly and
    // answer the whole batch with empty views, which handlers turn into
    // explicit per-request errors.
    let vocab = backend.vocab();
    let valid = all_ids.iter().all(|&i| i < vocab);
    if !valid {
        eprintln!("server bug: unvalidated id reached the batcher; \
                   rejecting the whole micro-batch");
    }
    let mut flat = vec![0.0f32; if valid { total * d } else { 0 }];
    if valid && cache.enabled() && d > 0 {
        // probe every slot; remember which positions missed
        let mut miss_pos: Vec<usize> = Vec::new();
        for (i, &id) in all_ids.iter().enumerate() {
            if !cache.try_copy(id, &mut flat[i * d..(i + 1) * d], stats) {
                miss_pos.push(i);
            }
        }
        if !miss_pos.is_empty() {
            // one pooled gather over the misses only (duplicate ids may
            // reconstruct twice within a batch -- harmless, identical
            // bits), then scatter back and admit the fresh rows
            let miss_ids: Vec<usize> =
                miss_pos.iter().map(|&i| all_ids[i]).collect();
            let mut miss_flat = vec![0.0f32; miss_ids.len() * d];
            backend.reconstruct_rows_into(&miss_ids, &mut miss_flat);
            for (m, &i) in miss_pos.iter().enumerate() {
                let row = &miss_flat[m * d..(m + 1) * d];
                flat[i * d..(i + 1) * d].copy_from_slice(row);
                cache.admit(all_ids[i], row);
            }
        }
        stats.ids_served.fetch_add(total as u64,
                                   std::sync::atomic::Ordering::Relaxed);
    } else if valid {
        backend.reconstruct_rows_into(&all_ids, &mut flat);
        stats.ids_served.fetch_add(total as u64,
                                   std::sync::atomic::Ordering::Relaxed);
    }
    // complete each request with a zero-copy view of the shared buffer
    let flat = Arc::new(flat);
    let mut off = 0;
    for p in batch {
        let len = if valid { p.ids.len() * d } else { 0 };
        p.complete(RowsSlice { buf: flat.clone(), start: off, len });
        off += len;
    }
    stats.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    stats.record_batch_secs(t0.elapsed().as_secs_f64());
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    use crate::dpq::{toy_embedding, CompressedEmbedding};

    fn toy_emb(n: usize, k: usize, dg: usize, s: usize) -> CompressedEmbedding {
        toy_embedding(n, k, dg, s, 1)
    }

    #[test]
    fn batch_queue_drains_up_to_max() {
        let q = BatchQueue::new(3);
        for _ in 0..5 {
            q.push(Pending::new(vec![0]).0);
        }
        let b1 = q.pop_batch(Duration::from_millis(1));
        assert_eq!(b1.len(), 3);
        let b2 = q.pop_batch(Duration::from_millis(1));
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn closed_queue_fails_pending_and_rejects_push() {
        let q = BatchQueue::new(4);
        let (p, done) = Pending::new(vec![1, 2]);
        assert!(q.push(p));
        q.close();
        // the queued pending was answered with the failure view
        assert_eq!(wait_rows(&done).as_slice().len(), 0);
        // a late push fails fast instead of stranding its waiter
        let (p2, done2) = Pending::new(vec![3]);
        assert!(!q.push(p2));
        assert_eq!(wait_rows(&done2).as_slice().len(), 0);
        assert!(q.is_closed());
        q.close(); // idempotent
        assert!(q.pop_batch(Duration::from_millis(1)).is_empty());
    }

    /// Regression for the poisoned-lock wedge: a thread that panics while
    /// holding the queue mutex poisons it, and the old `.unwrap()` in
    /// `pop_batch` then panicked the shard's batcher thread on every
    /// later drain -- permanently wedging the table. All queue ops must
    /// recover the guard and keep serving.
    #[test]
    fn poisoned_queue_keeps_serving() {
        let q = Arc::new(BatchQueue::new(4));
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            let _g = q2.inner.lock().unwrap();
            panic!("deliberate: poison the queue mutex");
        });
        assert!(t.join().is_err(), "the poisoning thread must panic");
        assert!(q.push(Pending::new(vec![1]).0));
        assert_eq!(q.pop_batch(Duration::from_millis(1)).len(), 1);
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        // a post-poison, post-close push still fails fast (no wedge)
        let (p, done) = Pending::new(vec![2]);
        assert!(!q.push(p));
        assert_eq!(wait_rows(&done).as_slice().len(), 0);
    }

    /// Same recovery on the completion slot: a handler that panicked
    /// while holding its slot mutex must not make `complete`/`wait_rows`
    /// panic in the batcher or another waiter.
    #[test]
    fn poisoned_done_slot_still_answers() {
        let (p, done) = Pending::new(vec![0]);
        let d2 = done.clone();
        let t = std::thread::spawn(move || {
            let _g = d2.0.lock().unwrap();
            panic!("deliberate: poison the slot mutex");
        });
        assert!(t.join().is_err());
        p.fail();
        assert_eq!(wait_rows(&done).as_slice().len(), 0);
    }

    /// The sharded batcher must split the flat reconstruction back into
    /// per-request slices in queue order, matching per-row reconstruction
    /// exactly for every thread count.
    #[test]
    fn run_batch_splits_per_request_and_matches_serial() {
        let emb = toy_emb(40, 8, 4, 3);
        let stats = Stats::default();
        let cache = RowCache::new(emb.d, 0); // disabled: the legacy path
        let reqs: Vec<Vec<usize>> =
            vec![vec![0, 5, 39], vec![], vec![7], vec![39, 0, 0, 12]];
        for threads in [1usize, 2, 7] {
            crate::util::pool::with_threads(threads, || {
                let batch: Vec<Pending> =
                    reqs.iter().map(|ids| Pending::new(ids.clone()).0).collect();
                run_batch(&emb, &batch, &stats, &cache);
                for (p, ids) in batch.iter().zip(&reqs) {
                    let rows = p.done.0.lock().unwrap().take().unwrap();
                    let flat = rows.as_slice();
                    assert_eq!(flat.len(), ids.len() * emb.d);
                    for (ri, &id) in ids.iter().enumerate() {
                        assert_eq!(
                            &flat[ri * emb.d..(ri + 1) * emb.d],
                            &emb.reconstruct_row(id)[..],
                            "threads={threads} req row {ri}"
                        );
                    }
                }
            });
        }
        assert_eq!(
            stats.ids_served.load(Ordering::Relaxed),
            3 * reqs.iter().map(|r| r.len()).sum::<usize>() as u64
        );
        assert_eq!(stats.batches.load(Ordering::Relaxed), 3);
        let (p50, p99) = stats.batch_latency().unwrap();
        assert!(p50 >= 0.0 && p99 >= p50);
    }

    /// The cache-enabled gather path must serve bit-identical rows to
    /// the cache-disabled path -- cold (all misses), warm (all hits),
    /// and mixed batches, at several thread counts -- while the hit and
    /// miss counters track exactly.
    #[test]
    fn run_batch_with_cache_is_bit_identical_and_counts() {
        let emb = toy_emb(40, 8, 4, 3);
        let want: Vec<Vec<f32>> =
            (0..40).map(|i| emb.reconstruct_row(i)).collect();
        for threads in [1usize, 2, 7] {
            let stats = Stats::default();
            let cache = RowCache::new(emb.d, 1 << 20);
            crate::util::pool::with_threads(threads, || {
                for ids in [vec![0usize, 5, 39, 5], // cold + in-batch dup
                            vec![0, 5, 39],         // fully warm
                            vec![5, 11, 0]]         // mixed
                {
                    let batch = vec![Pending::new(ids.clone()).0];
                    run_batch(&emb, &batch, &stats, &cache);
                    let rows = batch[0].done.0.lock().unwrap().take().unwrap();
                    let flat = rows.as_slice();
                    for (ri, &id) in ids.iter().enumerate() {
                        let got = &flat[ri * emb.d..(ri + 1) * emb.d];
                        assert!(
                            got.iter().zip(&want[id])
                                .all(|(a, b)| a.to_bits() == b.to_bits()),
                            "threads={threads} id={id}"
                        );
                    }
                }
            });
            // batch 1: 4 misses (the dup misses twice -- both probes
            // precede the admit); batch 2: 3 hits; batch 3: 2 hits + 1
            // miss (id 11 is cold)
            assert_eq!(stats.cache_misses.load(Ordering::Relaxed), 5,
                       "threads={threads}");
            assert_eq!(stats.cache_hits.load(Ordering::Relaxed), 5,
                       "threads={threads}");
            assert!(cache.bytes() <= cache.cap_bytes());
        }
    }
}
